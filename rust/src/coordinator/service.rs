//! The prediction service: batches model queries through the AOT-compiled
//! HLO pipelines (the request-path hot loop — Python is never involved).
//!
//! Falls back to the Rust reference model when constructed without a PJRT
//! engine (`PredictionService::reference()`), so every caller works in
//! both modes and the two paths can be compared (see `tests/hlo_parity.rs`).

use anyhow::Result;

use crate::counters::{Channel, ProfiledRun};
use crate::model::signature::{BandwidthSignature, ChannelSignature};
use crate::model::{apply, fit};
use crate::runtime::{batches, Batch, Engine, Tensor};

/// One §5 fit request: the two profiling runs.
#[derive(Clone, Debug)]
pub struct FitRequest {
    pub sym: ProfiledRun,
    pub asym: ProfiledRun,
}

/// One §6.2.2 counter-prediction query.
#[derive(Clone, Debug)]
pub struct CounterQuery {
    pub sig: ChannelSignature,
    pub threads: [usize; 2],
    /// Total traffic issued by each socket's threads (bytes).
    pub cpu_totals: [f64; 2],
}

/// One Fig-1-style performance query.
#[derive(Clone, Debug)]
pub struct PerfQuery {
    pub sig: ChannelSignature,
    pub threads: [usize; 2],
    /// Per-thread full-speed (read, write) demand, bytes/s.
    pub demand_pt: [f64; 2],
    /// Resource capacities (layout per `topology` / Python model).
    pub caps: [f64; 8],
}

enum Backend {
    Hlo(Engine),
    Reference,
}

pub struct PredictionService {
    backend: Backend,
}

impl PredictionService {
    /// Serve through the compiled HLO artifacts.
    pub fn hlo(engine: Engine) -> PredictionService {
        PredictionService {
            backend: Backend::Hlo(engine),
        }
    }

    /// Serve through the Rust reference model (no PJRT).
    pub fn reference() -> PredictionService {
        PredictionService {
            backend: Backend::Reference,
        }
    }

    /// Try HLO, fall back to reference with a warning.
    pub fn auto() -> PredictionService {
        match Engine::from_env() {
            Ok(engine) => PredictionService::hlo(engine),
            Err(e) => {
                eprintln!(
                    "numabw: PJRT engine unavailable ({e}); using the Rust \
                     reference model"
                );
                PredictionService::reference()
            }
        }
    }

    pub fn is_hlo(&self) -> bool {
        matches!(self.backend, Backend::Hlo(_))
    }

    // ---- fitting -----------------------------------------------------------

    /// Fit full signatures for a batch of run pairs.
    pub fn fit(&self, reqs: &[FitRequest]) -> Result<Vec<BandwidthSignature>> {
        match &self.backend {
            Backend::Reference => Ok(reqs
                .iter()
                .map(|r| fit::fit_run_pair(&r.sym, &r.asym))
                .collect()),
            Backend::Hlo(engine) => self.fit_hlo(engine, reqs),
        }
    }

    fn fit_hlo(&self, engine: &Engine, reqs: &[FitRequest])
        -> Result<Vec<BandwidthSignature>> {
        // 3 rows per request: read, write, combined.
        #[derive(Clone, Copy)]
        enum Row {
            Ch(Channel),
            Combined,
        }
        let rows: Vec<(usize, Row)> = reqs
            .iter()
            .enumerate()
            .flat_map(|(i, _)| {
                [
                    (i, Row::Ch(Channel::Read)),
                    (i, Row::Ch(Channel::Write)),
                    (i, Row::Combined),
                ]
            })
            .collect();

        let counts_row = |run: &ProfiledRun, row: Row| -> Vec<f32> {
            let m = match row {
                Row::Ch(ch) => run.counters.bank_matrix(ch),
                Row::Combined => {
                    let r = run.counters.bank_matrix(Channel::Read);
                    let w = run.counters.bank_matrix(Channel::Write);
                    r.iter()
                        .zip(&w)
                        .map(|(a, b)| [a[0] + b[0], a[1] + b[1]])
                        .collect()
                }
            };
            m.iter().flat_map(|b| [b[0] as f32, b[1] as f32]).collect()
        };
        let rates_row = |run: &ProfiledRun| -> Vec<f32> {
            run.thread_rates().iter().map(|&r| r as f32).collect()
        };

        let cap = engine.batch();
        let mut out: Vec<Option<ChannelSignature>> = vec![None; rows.len()];
        for (start, len) in batches(rows.len(), cap) {
            let chunk = &rows[start..start + len];
            let b = Batch::new(len, cap);
            let sym_c = b.pack(
                &chunk
                    .iter()
                    .map(|&(i, row)| counts_row(&reqs[i].sym, row))
                    .collect::<Vec<_>>(),
                &[2, 2],
            );
            let sym_r = b.pack(
                &chunk
                    .iter()
                    .map(|&(i, _)| rates_row(&reqs[i].sym))
                    .collect::<Vec<_>>(),
                &[2],
            );
            let asym_c = b.pack(
                &chunk
                    .iter()
                    .map(|&(i, row)| counts_row(&reqs[i].asym, row))
                    .collect::<Vec<_>>(),
                &[2, 2],
            );
            let asym_r = b.pack(
                &chunk
                    .iter()
                    .map(|&(i, _)| rates_row(&reqs[i].asym))
                    .collect::<Vec<_>>(),
                &[2],
            );
            let thr = b.pack(
                &chunk
                    .iter()
                    .map(|&(i, _)| {
                        reqs[i]
                            .asym
                            .threads_per_socket
                            .iter()
                            .map(|&t| t as f32)
                            .collect()
                    })
                    .collect::<Vec<_>>(),
                &[2],
            );
            let result = engine
                .execute("fit_signature", &[sym_c, sym_r, asym_c, asym_r,
                                            thr])?;
            let fracs = b.unpack(&result[0]);
            let onehot = b.unpack(&result[1]);
            let misfit = b.unpack(&result[2]);
            for (j, _) in chunk.iter().enumerate() {
                let f = &fracs[j];
                let sock = if onehot[j][0] >= onehot[j][1] { 0 } else { 1 };
                out[start + j] = Some(ChannelSignature {
                    static_frac: f[0] as f64,
                    local_frac: f[1] as f64,
                    perthread_frac: f[2] as f64,
                    static_socket: sock,
                    misfit: misfit[j][0] as f64,
                });
            }
        }

        Ok(reqs
            .iter()
            .enumerate()
            .map(|(i, r)| BandwidthSignature {
                read: out[3 * i].unwrap(),
                write: out[3 * i + 1].unwrap(),
                combined: out[3 * i + 2].unwrap(),
                read_bytes: r.sym.counters.channel_total(Channel::Read),
                write_bytes: r.sym.counters.channel_total(Channel::Write),
            })
            .collect())
    }

    // ---- counter prediction -------------------------------------------------

    /// Predict per-bank `(local, remote)` bytes for each query.
    pub fn predict_counters(&self, queries: &[CounterQuery])
        -> Result<Vec<Vec<[f64; 2]>>> {
        match &self.backend {
            Backend::Reference => Ok(queries
                .iter()
                .map(|q| {
                    apply::predict_counters(&q.sig, &q.threads,
                                            &q.cpu_totals)
                })
                .collect()),
            Backend::Hlo(engine) => {
                let cap = engine.batch();
                let mut out = Vec::with_capacity(queries.len());
                for (start, len) in batches(queries.len(), cap) {
                    let chunk = &queries[start..start + len];
                    let b = Batch::new(len, cap);
                    let tensors =
                        Self::pack_counter_queries(&b, chunk);
                    let result =
                        engine.execute("predict_counters", &tensors)?;
                    for row in b.unpack(&result[0]) {
                        out.push(vec![
                            [row[0] as f64, row[1] as f64],
                            [row[2] as f64, row[3] as f64],
                        ]);
                    }
                }
                Ok(out)
            }
        }
    }

    fn pack_counter_queries(b: &Batch, chunk: &[CounterQuery])
        -> Vec<Tensor> {
        let fracs = b.pack(
            &chunk
                .iter()
                .map(|q| {
                    vec![
                        q.sig.static_frac as f32,
                        q.sig.local_frac as f32,
                        q.sig.perthread_frac as f32,
                    ]
                })
                .collect::<Vec<_>>(),
            &[3],
        );
        let onehot = b.pack(
            &chunk
                .iter()
                .map(|q| {
                    let mut v = vec![0.0f32; 2];
                    v[q.sig.static_socket] = 1.0;
                    v
                })
                .collect::<Vec<_>>(),
            &[2],
        );
        let threads = b.pack(
            &chunk
                .iter()
                .map(|q| vec![q.threads[0] as f32, q.threads[1] as f32])
                .collect::<Vec<_>>(),
            &[2],
        );
        let totals = b.pack(
            &chunk
                .iter()
                .map(|q| {
                    vec![q.cpu_totals[0] as f32, q.cpu_totals[1] as f32]
                })
                .collect::<Vec<_>>(),
            &[2],
        );
        vec![fracs, onehot, threads, totals]
    }

    // ---- performance prediction ----------------------------------------------

    /// Max-min achieved bytes/s per flow (layout: `src*4 + dst*2 + rw`).
    pub fn predict_performance(&self, queries: &[PerfQuery])
        -> Result<Vec<Vec<f64>>> {
        match &self.backend {
            Backend::Reference => Ok(queries
                .iter()
                .map(Self::perf_reference)
                .collect()),
            Backend::Hlo(engine) => {
                let cap = engine.batch();
                let mut out = Vec::with_capacity(queries.len());
                for (start, len) in batches(queries.len(), cap) {
                    let chunk = &queries[start..start + len];
                    let b = Batch::new(len, cap);
                    let mut tensors = Self::pack_counter_queries(
                        &b,
                        &chunk
                            .iter()
                            .map(|q| CounterQuery {
                                sig: q.sig,
                                threads: q.threads,
                                cpu_totals: [0.0, 0.0],
                            })
                            .collect::<Vec<_>>(),
                    );
                    tensors.pop(); // drop cpu_totals
                    tensors.push(b.pack(
                        &chunk
                            .iter()
                            .map(|q| {
                                vec![q.demand_pt[0] as f32,
                                     q.demand_pt[1] as f32]
                            })
                            .collect::<Vec<_>>(),
                        &[2],
                    ));
                    tensors.push(b.pack(
                        &chunk
                            .iter()
                            .map(|q| {
                                q.caps.iter().map(|&c| c as f32).collect()
                            })
                            .collect::<Vec<_>>(),
                        &[8],
                    ));
                    let result =
                        engine.execute("predict_performance", &tensors)?;
                    for row in b.unpack(&result[0]) {
                        out.push(row.iter().map(|&v| v as f64).collect());
                    }
                }
                Ok(out)
            }
        }
    }

    /// Reference twin of the `predict_performance` pipeline.
    fn perf_reference(q: &PerfQuery) -> Vec<f64> {
        use crate::simulator::contention::{maxmin, Flow};
        let m = apply::apply(&q.sig, &q.threads);
        let mut flows = Vec::with_capacity(8);
        for src in 0..2 {
            for dst in 0..2 {
                for rw in 0..2 {
                    let demand = q.threads[src] as f64
                        * m[src][dst]
                        * q.demand_pt[rw];
                    // Resource layout mirrors model.py build_incidence.
                    let mut rs = vec![if rw == 0 { dst } else { 2 + dst }];
                    if src != dst {
                        rs.push(if rw == 0 {
                            4 + if dst == 0 { 0 } else { 1 }
                        } else {
                            6 + if src == 0 { 0 } else { 1 }
                        });
                    }
                    flows.push(Flow::new(demand, &rs));
                }
            }
        }
        maxmin(&flows, &q.caps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::counters::CounterSnapshot;
    use crate::model::signature::ChannelSignature;

    fn run_with(sig: &ChannelSignature, tps: &[usize]) -> ProfiledRun {
        let m = apply::apply(sig, tps);
        let mut c = CounterSnapshot::new(2);
        for (src, &n) in tps.iter().enumerate() {
            for dst in 0..2 {
                let bytes = m[src][dst] * n as f64 * 1e9;
                c.record_traffic(src, dst, Channel::Read, bytes);
                c.record_traffic(src, dst, Channel::Write, bytes * 0.5);
            }
            c.sockets[src].instructions = n as f64 * 1e9;
        }
        c.elapsed_s = 1.0;
        ProfiledRun {
            counters: c,
            threads_per_socket: tps.to_vec(),
        }
    }

    #[test]
    fn reference_fit_roundtrip() {
        let truth = ChannelSignature::new(0.2, 0.35, 0.3, 1);
        let svc = PredictionService::reference();
        let req = FitRequest {
            sym: run_with(&truth, &[2, 2]),
            asym: run_with(&truth, &[3, 1]),
        };
        let sigs = svc.fit(&[req]).unwrap();
        assert!((sigs[0].read.static_frac - 0.2).abs() < 1e-9);
        assert!((sigs[0].write.local_frac - 0.35).abs() < 1e-9);
        assert!((sigs[0].combined.perthread_frac - 0.3).abs() < 1e-9);
        assert!((sigs[0].read_share() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn reference_counter_prediction_matches_apply() {
        let sig = ChannelSignature::new(0.2, 0.35, 0.3, 1);
        let svc = PredictionService::reference();
        let q = CounterQuery {
            sig,
            threads: [3, 1],
            cpu_totals: [3.0, 1.0],
        };
        let pred = svc.predict_counters(&[q]).unwrap();
        assert!((pred[0][0][0] - 1.95).abs() < 1e-9);
        assert!((pred[0][1][1] - 1.05).abs() < 1e-9);
    }

    #[test]
    fn reference_perf_prediction_respects_caps() {
        let svc = PredictionService::reference();
        let q = PerfQuery {
            sig: ChannelSignature::new(1.0, 0.0, 0.0, 0),
            threads: [4, 4],
            demand_pt: [10.0, 0.0],
            caps: [40.0, 40.0, 40.0, 40.0, 6.4, 6.4, 9.2, 9.2],
        };
        let alloc = svc.predict_performance(&[q]).unwrap();
        let total: f64 = alloc[0].iter().sum();
        // Same scenario as the python test: channel 0 caps the total at 40.
        assert!((total - 40.0).abs() < 1e-6, "{alloc:?}");
    }
}
