//! Placement advisor: the Pandia-style serving use case from the paper's
//! introduction ("systems such as Pandia which take an application and
//! predict the performance and system load of a proposed thread count and
//! placement").
//!
//! Given a machine and a fitted bandwidth signature, the advisor
//! enumerates **every** valid thread placement, scores each by predicted
//! achieved bandwidth under the §4 + max-min contention pipeline (the same
//! what-if query loop thread-migration strategies need), and returns a
//! deterministic ranking.  All scoring goes through
//! [`PredictionService::serve_perf`] — the batched, placement-memoized
//! serving path — so a sweep costs one batched pass and repeated sweeps
//! cost cache lookups.  [`advise_brute_force`] is the per-query oracle the
//! integration tests pin the ranking against (bit-identical in reference
//! mode).
//!
//! Scores carry a secondary signal, **interconnect headroom**: the
//! smallest residual capacity fraction across the QPI links, i.e. how
//! close the placement drives the interconnect to saturation.  Ties on
//! predicted bandwidth break on headroom, then on lexicographic placement
//! order, so rankings are reproducible byte-for-byte.

use anyhow::{bail, Result};

use crate::model::signature::BandwidthSignature;
use crate::simulator::{Simulator, ThreadPlacement};
use crate::topology::MachineTopology;
use crate::workloads::WorkloadSpec;

use super::profiler::profile;
use super::service::{
    flow_resources, FitRequest, PerfQuery, PerfServer, PredictionService,
};

/// One scored placement.
#[derive(Clone, Debug)]
pub struct PlacementScore {
    pub placement: ThreadPlacement,
    /// Predicted achieved bandwidth (bytes/s), summed over all flows.
    pub predicted_bw: f64,
    /// Bandwidth the threads would demand uncontended (bytes/s).
    pub demanded_bw: f64,
    /// Smallest residual capacity fraction across the interconnect links
    /// (1.0 = QPI untouched, 0.0 = some link saturated).
    pub qpi_headroom: f64,
}

impl PlacementScore {
    /// Fraction of demand the placement is predicted to satisfy.
    pub fn satisfaction(&self) -> f64 {
        if self.demanded_bw > 0.0 {
            self.predicted_bw / self.demanded_bw
        } else {
            1.0
        }
    }
}

/// A ranked recommendation.
#[derive(Clone, Debug)]
pub struct Advice {
    pub workload: String,
    pub machine: String,
    /// Best first.
    pub ranked: Vec<PlacementScore>,
}

impl Advice {
    pub fn best(&self) -> &PlacementScore {
        &self.ranked[0]
    }
}

/// Enumerate every distribution of `total` threads over the machine's
/// sockets, one thread per core, in lexicographic order.  Generalises
/// [`ThreadPlacement::all_splits`] to any socket count.
pub fn enumerate_placements(machine: &MachineTopology, total: usize)
    -> Vec<ThreadPlacement> {
    fn rec(sockets: usize, cores: usize, left: usize,
           prefix: &mut Vec<usize>, out: &mut Vec<ThreadPlacement>) {
        if prefix.len() + 1 == sockets {
            if left <= cores {
                prefix.push(left);
                out.push(ThreadPlacement::new(prefix.clone()));
                prefix.pop();
            }
            return;
        }
        let remaining = sockets - prefix.len() - 1;
        for t in 0..=left.min(cores) {
            if left - t <= remaining * cores {
                prefix.push(t);
                rec(sockets, cores, left - t, prefix, out);
                prefix.pop();
            }
        }
    }
    let mut out = Vec::new();
    if total > 0 && total <= machine.total_cores() {
        let mut prefix = Vec::with_capacity(machine.sockets);
        rec(machine.sockets, machine.cores_per_socket, total, &mut prefix,
            &mut out);
    }
    out
}

/// Build the performance query scoring one placement: the per-thread
/// demand is latency-adjusted from the signature's own traffic matrix
/// (dependent-load workloads slow down when their accesses go remote —
/// the same issue-rate model the simulator uses).  Socket-count-generic:
/// the query carries the machine's full `2S + 2S(S-1)` capacity vector
/// and a length-S placement.
pub fn placement_query(machine: &MachineTopology, workload: &WorkloadSpec,
                       sig: &BandwidthSignature,
                       placement: &ThreadPlacement) -> PerfQuery {
    let caps = machine.capacities();
    let mut scratch = QueryScratch::default();
    placement_query_cached(machine, workload, sig, placement, &caps,
                           &mut scratch)
}

/// Reused per-sweep scratch of the advisor scoring path: the §4 matrix
/// buffer and the per-resource load vector that used to be fresh
/// allocations per placement ([`advise`] scores hundreds of placements
/// per call — `quad4` alone enumerates 165).
#[derive(Default)]
struct QueryScratch {
    /// [`crate::model::apply::apply_into`] target.
    m: Vec<Vec<f64>>,
    /// Per-resource loads of [`qpi_headroom`].
    loads: Vec<f64>,
}

/// [`placement_query`] against a hoisted capacity vector and reused
/// matrix scratch — the same floating-point operations (capacities don't
/// depend on the placement; the matrix buffer only changes *where* the
/// §4 values land), so served scores are bit-identical to the
/// allocate-per-placement path.
fn placement_query_cached(machine: &MachineTopology,
                          workload: &WorkloadSpec,
                          sig: &BandwidthSignature,
                          placement: &ThreadPlacement, caps: &[f64],
                          scratch: &mut QueryScratch) -> PerfQuery {
    let peak = workload.bw_per_thread.min(machine.core_peak_bw);
    crate::model::apply::apply_into(&sig.combined,
                                    &placement.threads_per_socket,
                                    &mut scratch.m);
    let m = &scratch.m;
    let n = placement.total().max(1) as f64;
    let mut lat = 0.0;
    for (src, &cnt) in placement.threads_per_socket.iter().enumerate() {
        for (dst, w) in m[src].iter().enumerate() {
            lat += cnt as f64 / n * w * machine.latency_ns(src, dst);
        }
    }
    let scale = (1.0 - workload.latency_sensitivity)
        + workload.latency_sensitivity * machine.local_latency_ns()
            / lat.max(machine.local_latency_ns());
    let per_thread = peak * scale;
    PerfQuery {
        sig: sig.combined,
        threads: placement.threads_per_socket.clone(),
        demand_pt: [
            per_thread * workload.read_fraction,
            per_thread * (1.0 - workload.read_fraction),
        ],
        caps: caps.to_vec(),
    }
}

/// Per-resource loads implied by an allocation (flow layout
/// `(src*S + dst)*2 + rw`; resource footprint via the shared
/// [`flow_resources`]), reduced to the QPI headroom: the smallest residual
/// capacity fraction across the `2S(S-1)` interconnect link directions.
fn qpi_headroom(q: &PerfQuery, alloc: &[f64], loads: &mut Vec<f64>) -> f64 {
    let s = q.sockets();
    loads.clear();
    loads.resize(2 * s * s, 0.0f64);
    for src in 0..s {
        for dst in 0..s {
            for rw in 0..2 {
                let a = alloc[(src * s + dst) * 2 + rw];
                let (chan, link) = flow_resources(s, src, dst, rw);
                loads[chan] += a;
                if let Some(l) = link {
                    loads[l] += a;
                }
            }
        }
    }
    (2 * s..2 * s * s)
        .map(|r| {
            if q.caps[r] > 0.0 {
                1.0 - loads[r] / q.caps[r]
            } else {
                0.0
            }
        })
        .fold(1.0, f64::min)
        .clamp(0.0, 1.0)
}

fn score_one(placement: ThreadPlacement, q: &PerfQuery, alloc: &[f64],
             loads: &mut Vec<f64>) -> PlacementScore {
    PlacementScore {
        demanded_bw: placement.total() as f64
            * (q.demand_pt[0] + q.demand_pt[1]),
        placement,
        predicted_bw: alloc.iter().sum(),
        qpi_headroom: qpi_headroom(q, alloc, loads),
    }
}

/// Deterministic ranking: predicted bandwidth desc, then headroom desc,
/// then lexicographic placement.
fn rank(scores: &mut [PlacementScore]) {
    scores.sort_by(|a, b| {
        b.predicted_bw
            .total_cmp(&a.predicted_bw)
            .then(b.qpi_headroom.total_cmp(&a.qpi_headroom))
            .then(
                a.placement
                    .threads_per_socket
                    .cmp(&b.placement.threads_per_socket),
            )
    });
}

/// Rank every valid placement of `total` threads through the batched,
/// cached serving path.  Generic over [`PerfServer`], so scoring runs
/// identically against an in-process [`PredictionService`] or a
/// [`crate::server::Client`] handle into the concurrent coalescing
/// front-end.
pub fn advise<S: PerfServer + ?Sized>(svc: &S, machine: &MachineTopology,
              workload: &WorkloadSpec, sig: &BandwidthSignature,
              total: usize) -> Result<Advice> {
    // Hand-built topologies reach the advisor unvalidated (files and
    // discovery validate on load, struct literals don't): reject shape
    // errors here instead of letting the index arithmetic panic.
    if let Err(e) = machine.validate() {
        bail!("invalid machine topology: {e}");
    }
    if sig.combined.static_socket >= machine.sockets {
        bail!(
            "signature's static socket {} does not exist on {} \
             ({} sockets) — it was fitted for a different machine",
            sig.combined.static_socket,
            machine.name,
            machine.sockets
        );
    }
    let placements = enumerate_placements(machine, total);
    if placements.is_empty() {
        bail!(
            "no valid placement of {total} threads on {} ({} cores)",
            machine.name,
            machine.total_cores()
        );
    }
    let caps = machine.capacities();
    let mut scratch = QueryScratch::default();
    let queries: Vec<PerfQuery> = placements
        .iter()
        .map(|p| {
            placement_query_cached(machine, workload, sig, p, &caps,
                                   &mut scratch)
        })
        .collect();
    let allocs = svc.serve_perf(&queries)?;
    let mut ranked: Vec<PlacementScore> = placements
        .into_iter()
        .zip(&queries)
        .zip(&allocs)
        .map(|((p, q), alloc)| score_one(p, q, alloc, &mut scratch.loads))
        .collect();
    rank(&mut ranked);
    Ok(Advice {
        workload: workload.name.clone(),
        machine: machine.name.clone(),
        ranked,
    })
}

/// The per-query oracle: identical scoring, one unbatched, uncached
/// backend call per placement.  Exists so tests (and the throughput bench)
/// can pin the served ranking against first principles.
pub fn advise_brute_force(svc: &PredictionService,
                          machine: &MachineTopology,
                          workload: &WorkloadSpec,
                          sig: &BandwidthSignature, total: usize)
    -> Result<Advice> {
    if let Err(e) = machine.validate() {
        bail!("invalid machine topology: {e}");
    }
    if sig.combined.static_socket >= machine.sockets {
        bail!(
            "signature's static socket {} does not exist on {} \
             ({} sockets)",
            sig.combined.static_socket,
            machine.name,
            machine.sockets
        );
    }
    let placements = enumerate_placements(machine, total);
    if placements.is_empty() {
        bail!("no valid placement of {total} threads on {}", machine.name);
    }
    let caps = machine.capacities();
    let mut scratch = QueryScratch::default();
    let mut ranked = Vec::with_capacity(placements.len());
    for p in placements {
        let q = placement_query_cached(machine, workload, sig, &p, &caps,
                                       &mut scratch);
        let alloc = svc
            .predict_performance(std::slice::from_ref(&q))?
            .pop()
            .expect("one allocation per query");
        ranked.push(score_one(p, &q, &alloc, &mut scratch.loads));
    }
    rank(&mut ranked);
    Ok(Advice {
        workload: workload.name.clone(),
        machine: machine.name.clone(),
        ranked,
    })
}

/// Convenience end-to-end entry: profile the workload on the simulator
/// (two §5.1 runs), fit its signature, and advise.  `total` defaults to
/// one socket's worth of cores (the paper's evaluation convention).
pub fn advise_workload(svc: &PredictionService, sim: &Simulator,
                       workload: &WorkloadSpec, total: Option<usize>)
    -> Result<Advice> {
    let total = total.unwrap_or(sim.machine.cores_per_socket);
    let pair = profile(sim, workload);
    let sig = svc
        .fit(&[FitRequest {
            sym: pair.sym,
            asym: pair.asym,
        }])?
        .pop()
        .expect("one signature per fit request");
    advise(svc, &sim.machine, workload, &sig, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SimConfig;
    use crate::workloads::suite;

    fn m8() -> MachineTopology {
        MachineTopology::xeon_e5_2630_v3()
    }

    #[test]
    fn enumeration_matches_all_splits_on_two_sockets() {
        for total in [4, 8, 12] {
            let ours = enumerate_placements(&m8(), total);
            let splits = ThreadPlacement::all_splits(&m8(), total);
            assert_eq!(ours, splits, "total={total}");
        }
    }

    #[test]
    fn enumeration_generalises_to_more_sockets() {
        let mut m = m8();
        m.sockets = 3;
        m.cores_per_socket = 2;
        let ps = enumerate_placements(&m, 4);
        // Compositions of 4 into 3 parts, each <= 2:
        // (0,2,2) (1,1,2) (1,2,1) (2,0,2) (2,1,1) (2,2,0).
        assert_eq!(ps.len(), 6);
        for p in &ps {
            assert_eq!(p.total(), 4);
            assert!(p.threads_per_socket.iter().all(|&t| t <= 2));
        }
        // Lexicographic order.
        for w in ps.windows(2) {
            assert!(w[0].threads_per_socket < w[1].threads_per_socket);
        }
    }

    #[test]
    fn enumeration_edge_cases() {
        assert!(enumerate_placements(&m8(), 0).is_empty());
        assert!(enumerate_placements(&m8(), 17).is_empty());
        assert_eq!(enumerate_placements(&m8(), 16).len(), 1);
    }

    #[test]
    fn headroom_is_one_without_remote_traffic() {
        let svc = PredictionService::reference();
        let w = suite::by_name("ep").unwrap(); // almost purely local
        let sim = Simulator::new(m8(), SimConfig::noiseless());
        let advice =
            advise_workload(&svc, &sim, &w, Some(4)).unwrap();
        // Some placement keeps everything local -> full QPI headroom.
        assert!(advice
            .ranked
            .iter()
            .any(|s| s.qpi_headroom > 0.99));
        for s in &advice.ranked {
            assert!((0.0..=1.0).contains(&s.qpi_headroom));
            assert!(s.predicted_bw <= s.demanded_bw * (1.0 + 1e-9));
        }
    }

    #[test]
    fn ranking_is_deterministic_across_calls() {
        let svc = PredictionService::reference();
        let sim = Simulator::new(m8(), SimConfig::default());
        let w = suite::by_name("cg").unwrap();
        let a = advise_workload(&svc, &sim, &w, Some(8)).unwrap();
        let b = advise_workload(&svc, &sim, &w, Some(8)).unwrap();
        let order = |adv: &Advice| -> Vec<Vec<usize>> {
            adv.ranked
                .iter()
                .map(|s| s.placement.threads_per_socket.clone())
                .collect()
        };
        assert_eq!(order(&a), order(&b));
    }

    fn handmade_sig(static_socket: usize)
        -> crate::model::signature::BandwidthSignature {
        let ch = crate::model::signature::ChannelSignature::new(
            0.2, 0.3, 0.3, static_socket);
        crate::model::signature::BandwidthSignature {
            read: ch,
            write: ch,
            combined: ch,
            read_bytes: 1.0,
            write_bytes: 1.0,
        }
    }

    #[test]
    fn four_socket_machines_are_advised_not_rejected() {
        // Regression: this call used to die in `placement_query` on the
        // 2-socket `caps` conversion (`expect("advisor requires the
        // 2-socket resource layout")`).
        let m = MachineTopology::uniform(
            "xeon8-but-wider", 4, 8, 44.0 * crate::topology::GB,
            30.0 * crate::topology::GB, 7.04 * crate::topology::GB,
            6.9 * crate::topology::GB, 90.0, 200.0,
            5.5 * crate::topology::GB, 667.0);
        let svc = PredictionService::reference();
        let w = suite::by_name("cg").unwrap();
        let advice = advise(&svc, &m, &w, &handmade_sig(0), 8).unwrap();
        assert!(!advice.ranked.is_empty());
        for s in &advice.ranked {
            assert_eq!(s.placement.threads_per_socket.len(), 4);
            assert_eq!(s.placement.total(), 8);
            assert!(s.predicted_bw.is_finite());
            assert!((0.0..=1.0).contains(&s.qpi_headroom));
        }
        // Brute force agrees bit-for-bit on S=4, exactly as on S=2.
        let brute =
            advise_brute_force(&svc, &m, &w, &handmade_sig(0), 8).unwrap();
        for (a, b) in advice.ranked.iter().zip(&brute.ranked) {
            assert_eq!(a.placement, b.placement);
            assert_eq!(a.predicted_bw.to_bits(), b.predicted_bw.to_bits());
            assert_eq!(a.qpi_headroom.to_bits(), b.qpi_headroom.to_bits());
        }
    }

    #[test]
    fn malformed_topology_is_a_typed_error_not_silent_nonsense() {
        // The old debug_assert!-only index checks meant a hand-built
        // topology with resized sockets but stale per-socket vectors
        // produced garbage resource indices in release builds.  Now both
        // advise paths validate first.
        let mut m = m8();
        m.sockets = 4; // vectors still sized for 2 sockets
        let svc = PredictionService::reference();
        let w = suite::by_name("cg").unwrap();
        let err = advise(&svc, &m, &w, &handmade_sig(0), 8).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("invalid machine topology"), "{msg}");
        assert!(msg.contains("chan_read_bw"), "{msg}");
        let err = advise_brute_force(&svc, &m, &w, &handmade_sig(0), 8)
            .unwrap_err();
        assert!(format!("{err}").contains("invalid machine topology"));
    }

    #[test]
    fn mismatched_signature_is_a_typed_error_not_a_panic() {
        // A signature fitted for a bigger machine (static socket 3) cannot
        // be applied to a 2-socket one: typed error, no assert.
        let svc = PredictionService::reference();
        let w = suite::by_name("cg").unwrap();
        let err = advise(&svc, &m8(), &w, &handmade_sig(3), 8).unwrap_err();
        assert!(format!("{err}").contains("static socket"), "{err}");
        let err = advise_brute_force(&svc, &m8(), &w, &handmade_sig(3), 8)
            .unwrap_err();
        assert!(format!("{err}").contains("static socket"), "{err}");
    }

    #[test]
    fn four_socket_workload_advises_end_to_end() {
        // Full path on the synthetic quad machine: simulator profiling,
        // fit through fit_multi, scoring through the generic flow layout.
        let svc = PredictionService::reference();
        let m = MachineTopology::synthetic_quad();
        let sim = Simulator::new(m, SimConfig::default());
        let w = suite::by_name("cg").unwrap();
        let advice = advise_workload(&svc, &sim, &w, Some(8)).unwrap();
        assert!(!advice.ranked.is_empty());
        assert_eq!(advice.best().placement.threads_per_socket.len(), 4);
        // Deterministic across calls.
        let again = advise_workload(&svc, &sim, &w, Some(8)).unwrap();
        assert_eq!(advice.best().placement, again.best().placement);
    }
}
