//! Minimal worker pool over `std::thread::scope` (tokio is not in the
//! offline vendor set, and the coordinator's parallelism is CPU-bound
//! fan-out over independent simulator runs — scoped threads are the right
//! tool anyway).

/// Map `f` over `items` in parallel, preserving order.  Spawns at most
/// `max_threads` workers (0 = available parallelism).
pub fn parallel_map<T, R, F>(items: Vec<T>, max_threads: usize, f: F)
    -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    let workers = if max_threads == 0 { hw } else { max_threads }
        .min(n)
        .max(1);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }

    // Work-stealing by atomic index over a shared input vector.
    use std::sync::atomic::{AtomicUsize, Ordering};
    let next = AtomicUsize::new(0);
    let inputs: Vec<std::sync::Mutex<Option<T>>> = items
        .into_iter()
        .map(|t| std::sync::Mutex::new(Some(t)))
        .collect();
    let outputs: Vec<std::sync::Mutex<Option<R>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().unwrap().take().unwrap();
                *outputs[i].lock().unwrap() = Some(f(item));
            });
        }
    });

    outputs
        .into_iter()
        .map(|m| m.into_inner().unwrap().unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect(), 0, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn actually_uses_multiple_threads() {
        use std::sync::Mutex;
        let ids: Mutex<Vec<String>> = Mutex::new(Vec::new());
        let _ = parallel_map((0..64).collect::<Vec<i32>>(), 4, |x| {
            ids.lock()
                .unwrap()
                .push(format!("{:?}", std::thread::current().id()));
            std::thread::sleep(std::time::Duration::from_millis(1));
            x
        });
        let mut v = ids.into_inner().unwrap();
        v.sort();
        v.dedup();
        assert!(v.len() > 1);
    }
}
