//! The §6.2.2 evaluation driver: profile → fit → sweep every thread
//! distribution → compare predicted against measured counters.
//!
//! For each benchmark, threads are fixed at the largest count a single
//! socket supports (one per core) and distributed across the two sockets
//! in every feasible split; for every split the simulator's measured
//! per-bank local/remote read/write counters are compared against the
//! model's predictions (read, write, and combined signatures), each
//! difference expressed as a percentage of the run's total traffic — the
//! paper's Fig 16/17/18 data.

use anyhow::Result;

use crate::counters::Channel;
use crate::model::signature::BandwidthSignature;
use crate::simulator::{Simulator, ThreadPlacement};
use crate::workloads::WorkloadSpec;

use super::pool::parallel_map;
use super::profiler::profile_suite;
use super::service::{CounterQuery, FitRequest, PredictionService};

/// One (workload × split × channel × bank × local/remote) comparison.
#[derive(Clone, Debug)]
pub struct ErrorRecord {
    pub workload: String,
    /// Threads per socket during the measured run.
    pub split: [usize; 2],
    /// "read", "write" or "combined".
    pub channel: &'static str,
    pub bank: usize,
    /// "local" or "remote".
    pub kind: &'static str,
    pub measured: f64,
    pub predicted: f64,
    /// |measured - predicted| as % of the run's total traffic.
    pub err_pct: f64,
    /// The run's aggregate bandwidth (bytes/s) — Fig 18's x-axis.
    pub run_bandwidth: f64,
}

/// Full evaluation output for one machine.
#[derive(Clone, Debug)]
pub struct Evaluation {
    pub machine: String,
    pub signatures: Vec<(String, BandwidthSignature)>,
    pub records: Vec<ErrorRecord>,
}

impl Evaluation {
    pub fn errors(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.err_pct).collect()
    }

    pub fn errors_for(&self, workload: &str) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| r.workload == workload)
            .map(|r| r.err_pct)
            .collect()
    }

    pub fn signature(&self, workload: &str) -> Option<&BandwidthSignature> {
        self.signatures
            .iter()
            .find(|(n, _)| n == workload)
            .map(|(_, s)| s)
    }
}

/// CPU-side totals per socket for a channel: a socket's traffic is its own
/// bank's local counter plus the other bank's remote counter (S=2).
fn cpu_totals(m: &[[f64; 2]]) -> [f64; 2] {
    [m[0][0] + m[1][1], m[1][0] + m[0][1]]
}

fn combined_matrix(run: &crate::counters::CounterSnapshot)
    -> Vec<[f64; 2]> {
    let r = run.bank_matrix(Channel::Read);
    let w = run.bank_matrix(Channel::Write);
    r.iter()
        .zip(&w)
        .map(|(a, b)| [a[0] + b[0], a[1] + b[1]])
        .collect()
}

/// Evaluate a workload suite on a simulated machine.
///
/// `thread_total` defaults to the machine's cores-per-socket (the paper's
/// "largest thread count supported by a single socket").
pub fn evaluate_suite(sim: &Simulator, svc: &PredictionService,
                      workloads: &[WorkloadSpec],
                      thread_total: Option<usize>) -> Result<Evaluation> {
    // 1. Profile: the two §5.1 runs per workload (parallel).
    let pairs = profile_suite(sim, workloads);

    // 2. Fit all signatures in one batched call.
    let reqs: Vec<FitRequest> = pairs
        .iter()
        .map(|p| FitRequest {
            sym: p.sym.clone(),
            asym: p.asym.clone(),
        })
        .collect();
    let sigs = svc.fit(&reqs)?;

    // 3. Sweep splits: measured runs in parallel.
    let total = thread_total.unwrap_or(sim.machine.cores_per_socket);
    let splits = ThreadPlacement::all_splits(&sim.machine, total);
    let measured: Vec<Vec<crate::simulator::RunResult>> = parallel_map(
        workloads.to_vec(),
        0,
        |w| {
            splits
                .iter()
                .map(|p| sim.run(&w, p))
                .collect::<Vec<_>>()
        },
    );

    // 4. Batch every prediction query, then diff.
    let mut queries = Vec::new();
    let mut query_meta = Vec::new();
    for (wi, _w) in workloads.iter().enumerate() {
        let sig = &sigs[wi];
        for (si, split) in splits.iter().enumerate() {
            let run = &measured[wi][si].run;
            for (channel, csig, matrix) in [
                ("read", sig.read, run.counters.bank_matrix(Channel::Read)),
                ("write", sig.write,
                 run.counters.bank_matrix(Channel::Write)),
                ("combined", sig.combined, combined_matrix(&run.counters)),
            ] {
                queries.push(CounterQuery {
                    sig: csig,
                    threads: split.threads_per_socket.clone(),
                    cpu_totals: cpu_totals(&matrix).to_vec(),
                });
                query_meta.push((wi, si, channel, matrix));
            }
        }
    }
    let predictions = svc.predict_counters(&queries)?;

    let mut records = Vec::new();
    for ((wi, si, channel, matrix), pred) in
        query_meta.into_iter().zip(predictions)
    {
        let run = &measured[wi][si];
        // Error denominator: the run's total traffic on the channel being
        // predicted (the paper fits and scores read and write signatures
        // separately; "total bandwidth" is that channel's total).
        let grand = matrix
            .iter()
            .map(|b| b[0] + b[1])
            .sum::<f64>()
            .max(1e-9);
        for bank in 0..2 {
            for (kind, k) in [("local", 0), ("remote", 1)] {
                let m = matrix[bank][k];
                let p = pred[bank][k];
                records.push(ErrorRecord {
                    workload: workloads[wi].name.clone(),
                    split: [
                        splits[si].threads_per_socket[0],
                        splits[si].threads_per_socket[1],
                    ],
                    channel,
                    bank,
                    kind,
                    measured: m,
                    predicted: p,
                    err_pct: 100.0 * (m - p).abs() / grand,
                    run_bandwidth: run.run.counters.bandwidth(),
                });
            }
        }
    }

    Ok(Evaluation {
        machine: sim.machine.name.clone(),
        signatures: workloads
            .iter()
            .zip(sigs)
            .map(|(w, s)| (w.name.clone(), s))
            .collect(),
        records,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SimConfig;
    use crate::topology::MachineTopology;
    use crate::util::stats::Cdf;
    use crate::workloads::suite;

    /// `cg` with its real-world messiness stripped: tests the *model*, not
    /// the testbed realism.
    fn ideal_cg() -> crate::workloads::WorkloadSpec {
        let mut w = suite::by_name("cg").unwrap();
        w.irregularity = 0.0;
        w.placement_drift = 0.0;
        w
    }

    #[test]
    fn conforming_workload_predicts_accurately() {
        // Noise-free, model-conforming workload → near-zero error.
        let sim = Simulator::new(MachineTopology::xeon_e5_2630_v3(),
                                 SimConfig::noiseless());
        let svc = PredictionService::reference();
        let ev = evaluate_suite(&sim, &svc, &[ideal_cg()], None).unwrap();
        assert!(!ev.records.is_empty());
        let cdf = Cdf::of(&ev.errors());
        assert!(cdf.median() < 1.0,
                "median error {}% should be tiny", cdf.median());
    }

    #[test]
    fn pagerank_misfits_worse_than_conforming() {
        let sim = Simulator::new(MachineTopology::xeon_e5_2630_v3(),
                                 SimConfig::noiseless());
        let svc = PredictionService::reference();
        let ws = vec![ideal_cg(), suite::by_name("pagerank").unwrap()];
        let ev = evaluate_suite(&sim, &svc, &ws, None).unwrap();
        let cg = Cdf::of(&ev.errors_for("cg")).quantile(0.9);
        let pr = Cdf::of(&ev.errors_for("pagerank")).quantile(0.9);
        assert!(pr > cg * 2.0, "pagerank p90={pr} cg p90={cg}");
        // And the misfit detector flags it (§6.2.1).
        let sig = ev.signature("pagerank").unwrap();
        assert!(sig.read.misfit > ev.signature("cg").unwrap().read.misfit);
    }

    #[test]
    fn point_count_scales_with_splits_and_channels() {
        let sim = Simulator::new(MachineTopology::xeon_e5_2630_v3(),
                                 SimConfig::noiseless());
        let svc = PredictionService::reference();
        let ws = vec![suite::by_name("ft").unwrap()];
        let ev = evaluate_suite(&sim, &svc, &ws, Some(8)).unwrap();
        // 9 splits × 3 channels × 2 banks × 2 kinds = 108.
        assert_eq!(ev.records.len(), 108);
    }
}
