//! Layer-3 coordination: profiling orchestration, batched prediction
//! serving through the AOT HLO pipelines, signature persistence, and the
//! paper's evaluation sweeps.
//!
//! * [`pool`]     — scoped-thread worker pool.
//! * [`profiler`] — §5.1 profiling-run orchestration.
//! * [`service`]  — the prediction service (HLO or Rust-reference backend).
//! * [`store`]    — persisted signature store.
//! * [`evaluate`] — the §6.2.2 measured-vs-predicted sweep.

pub mod evaluate;
pub mod pool;
pub mod profiler;
pub mod service;
pub mod store;

pub use evaluate::{evaluate_suite, ErrorRecord, Evaluation};
pub use profiler::{profile, profile_suite, ProfilePair};
pub use service::{CounterQuery, FitRequest, PerfQuery, PredictionService};
pub use store::SignatureStore;
