//! Layer-3 coordination: profiling orchestration, batched prediction
//! serving, the placement advisor, signature persistence, and the paper's
//! evaluation sweeps.
//!
//! * [`pool`]     — scoped-thread worker pool.
//! * [`profiler`] — §5.1 profiling-run orchestration.
//! * [`service`]  — the prediction service (HLO or Rust-reference
//!   backend), plus the batched+cached serving front-end
//!   (`serve_counters` / `serve_perf` / `CounterBatcher`).
//! * [`advisor`]  — Pandia-style placement advisor: enumerate + score +
//!   rank every valid placement through the serving path.
//! * [`store`]    — persisted signature store (deterministic ordering).
//! * [`evaluate`] — the §6.2.2 measured-vs-predicted sweep.

pub mod advisor;
pub mod evaluate;
pub mod pool;
pub mod profiler;
pub mod service;
pub mod store;

pub use advisor::{advise, advise_workload, Advice, PlacementScore};
pub use evaluate::{evaluate_suite, ErrorRecord, Evaluation};
pub use profiler::{profile, profile_suite, ProfilePair};
pub use service::{
    CacheStats, CounterBatcher, CounterQuery, FitRequest, PerfQuery,
    PerfServer, PredictionService,
};
pub use store::SignatureStore;
