//! Profiling orchestration: runs the §5.1 symmetric + asymmetric pair for
//! each workload on a simulated machine and hands the counter data to the
//! fit.
//!
//! The paper's note applies here too: if a performance-prediction tool
//! (Pandia) already does a symmetric measurement run, only the asymmetric
//! run is additional — [`ProfilePair`] keeps the two runs separate so a
//! caller can supply an existing symmetric run.

use crate::counters::ProfiledRun;
use crate::simulator::{Simulator, ThreadPlacement};
use crate::workloads::WorkloadSpec;

use super::pool::parallel_map;

/// The §5.1 run pair for one workload.
#[derive(Clone, Debug)]
pub struct ProfilePair {
    pub workload: String,
    pub sym: ProfiledRun,
    pub asym: ProfiledRun,
}

/// Run both profiling placements for one workload.
pub fn profile(sim: &Simulator, workload: &WorkloadSpec) -> ProfilePair {
    let total = ThreadPlacement::profiling_total(&sim.machine);
    let sym_p = ThreadPlacement::symmetric(&sim.machine, total)
        .expect("profiling_total guarantees a symmetric placement");
    let asym_p = ThreadPlacement::asymmetric(&sim.machine, total)
        .expect("profiling_total guarantees an asymmetric placement");
    ProfilePair {
        workload: workload.name.clone(),
        sym: sim.run(workload, &sym_p).run,
        asym: sim.run(workload, &asym_p).run,
    }
}

/// Profile a whole suite in parallel.
pub fn profile_suite(sim: &Simulator, workloads: &[WorkloadSpec])
    -> Vec<ProfilePair> {
    parallel_map(workloads.to_vec(), 0, |w| profile(sim, &w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SimConfig;
    use crate::topology::MachineTopology;
    use crate::workloads::suite;

    #[test]
    fn profile_pair_uses_distinct_placements() {
        let sim = Simulator::new(MachineTopology::xeon_e5_2630_v3(),
                                 SimConfig::noiseless());
        let w = suite::by_name("cg").unwrap();
        let pair = profile(&sim, &w);
        assert_eq!(pair.sym.threads_per_socket[0],
                   pair.sym.threads_per_socket[1]);
        assert_ne!(pair.asym.threads_per_socket[0],
                   pair.asym.threads_per_socket[1]);
        assert_eq!(pair.sym.total_threads(), pair.asym.total_threads());
        assert!(pair.sym.counters.grand_total() > 0.0);
    }

    #[test]
    fn suite_profiling_covers_all_workloads() {
        let sim = Simulator::new(MachineTopology::xeon_e5_2630_v3(),
                                 SimConfig::noiseless());
        let ws: Vec<_> = suite::table1().into_iter().take(4).collect();
        let pairs = profile_suite(&sim, &ws);
        assert_eq!(pairs.len(), 4);
        for (p, w) in pairs.iter().zip(&ws) {
            assert_eq!(p.workload, w.name);
        }
    }
}
