//! Self-contained substrates the offline build cannot pull from crates.io:
//! PRNG, JSON, CLI args, statistics, a deterministic LRU cache, and a
//! benchmark harness.

pub mod args;
pub mod bench;
pub mod json;
pub mod lru;
pub mod rng;
pub mod stats;
