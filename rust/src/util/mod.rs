//! Self-contained substrates the offline build cannot pull from crates.io:
//! PRNG, JSON, CLI args, statistics, and a benchmark harness.

pub mod args;
pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
