//! Deterministic LRU cache: the shared serving-cache substrate (the
//! crates.io `lru` crate is not in the offline vendor set).
//!
//! Determinism contract: eviction order is **recency-defined** — the entry
//! touched longest ago is evicted first, and recency is tracked with an
//! intrusive doubly-linked list over a slab, so eviction never depends on
//! `HashMap` iteration (hash) order.  Replaying the same sequence of
//! `get`/`insert` calls reproduces the same evictions byte-for-byte, which
//! is what lets the serving layer keep its bit-identity guarantee while
//! staying bounded.
//!
//! Every cache carries its own hit/miss/eviction counters
//! ([`CacheCounters`]) so the serving layer can report per-cache hit rates
//! instead of one aggregate number.

use std::collections::HashMap;
use std::hash::Hash;

/// Monotonic per-cache counters (since cache construction).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheCounters {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction in [0, 1]; 0 when the cache was never queried.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// Component-wise sum (for aggregate reporting).
    pub fn merged(&self, other: &CacheCounters) -> CacheCounters {
        CacheCounters {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            evictions: self.evictions + other.evictions,
        }
    }

    /// Component-wise sum over any number of counters — the roll-up the
    /// sharded serving layer renders next to its per-shard tables.
    pub fn merged_over<I>(counters: I) -> CacheCounters
    where
        I: IntoIterator<Item = CacheCounters>,
    {
        counters
            .into_iter()
            .fold(CacheCounters::default(), |acc, c| acc.merged(&c))
    }
}

const NIL: usize = usize::MAX;

struct Node<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

/// Bounded map with least-recently-used eviction.
///
/// `get` promotes the entry to most-recently-used and counts a hit;
/// a lookup of an absent key counts a miss.  `insert` beyond capacity
/// evicts the least-recently-used entry and returns it.
pub struct Lru<K, V> {
    map: HashMap<K, usize>,
    nodes: Vec<Option<Node<K, V>>>,
    free: Vec<usize>,
    /// Most-recently-used node (NIL when empty).
    head: usize,
    /// Least-recently-used node (NIL when empty).
    tail: usize,
    cap: usize,
    counters: CacheCounters,
}

impl<K: Eq + Hash + Clone, V> Lru<K, V> {
    pub fn new(cap: usize) -> Lru<K, V> {
        assert!(cap >= 1, "LRU capacity must be at least 1");
        Lru {
            map: HashMap::new(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            cap,
            counters: CacheCounters::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn counters(&self) -> CacheCounters {
        self.counters
    }

    fn node(&self, i: usize) -> &Node<K, V> {
        self.nodes[i].as_ref().expect("live LRU node")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node<K, V> {
        self.nodes[i].as_mut().expect("live LRU node")
    }

    fn unlink(&mut self, i: usize) {
        let (prev, next) = {
            let n = self.node(i);
            (n.prev, n.next)
        };
        if prev == NIL {
            self.head = next;
        } else {
            self.node_mut(prev).next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.node_mut(next).prev = prev;
        }
    }

    fn push_front(&mut self, i: usize) {
        let old_head = self.head;
        {
            let n = self.node_mut(i);
            n.prev = NIL;
            n.next = old_head;
        }
        if old_head != NIL {
            self.node_mut(old_head).prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Look up `k`, promoting it to most-recently-used on a hit.
    pub fn get(&mut self, k: &K) -> Option<&V> {
        match self.map.get(k).copied() {
            Some(i) => {
                self.counters.hits += 1;
                self.unlink(i);
                self.push_front(i);
                Some(&self.node(i).value)
            }
            None => {
                self.counters.misses += 1;
                None
            }
        }
    }

    /// Look up `k` without touching recency or counters.
    pub fn peek(&self, k: &K) -> Option<&V> {
        self.map.get(k).map(|&i| &self.node(i).value)
    }

    /// Insert (or refresh) `k`.  Returns the evicted least-recently-used
    /// entry if the insertion pushed the cache past capacity.
    pub fn insert(&mut self, k: K, v: V) -> Option<(K, V)> {
        if let Some(&i) = self.map.get(&k) {
            self.node_mut(i).value = v;
            self.unlink(i);
            self.push_front(i);
            return None;
        }
        let evicted = if self.map.len() >= self.cap {
            let t = self.tail;
            self.unlink(t);
            let node = self.nodes[t].take().expect("live LRU tail");
            self.map.remove(&node.key);
            self.free.push(t);
            self.counters.evictions += 1;
            Some((node.key, node.value))
        } else {
            None
        };
        let slot = match self.free.pop() {
            Some(i) => {
                self.nodes[i] = Some(Node {
                    key: k.clone(),
                    value: v,
                    prev: NIL,
                    next: NIL,
                });
                i
            }
            None => {
                self.nodes.push(Some(Node {
                    key: k.clone(),
                    value: v,
                    prev: NIL,
                    next: NIL,
                }));
                self.nodes.len() - 1
            }
        };
        self.map.insert(k, slot);
        self.push_front(slot);
        evicted
    }

    /// Drop every entry (administrative invalidation — counters are
    /// preserved, and nothing is recorded as an eviction).
    pub fn clear(&mut self) {
        self.map.clear();
        self.nodes.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
    }

    /// Keys from most- to least-recently-used (test/debug aid; this is the
    /// reverse of eviction order).
    pub fn keys_by_recency(&self) -> Vec<K> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut i = self.head;
        while i != NIL {
            let n = self.node(i);
            out.push(n.key.clone());
            i = n.next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_hits_and_misses_count() {
        let mut c: Lru<u32, u32> = Lru::new(4);
        c.insert(1, 10);
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(c.get(&2), None);
        let ctr = c.counters();
        assert_eq!((ctr.hits, ctr.misses, ctr.evictions), (1, 1, 0));
        assert!((ctr.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn eviction_is_least_recently_used() {
        let mut c: Lru<&str, u32> = Lru::new(3);
        c.insert("a", 1);
        c.insert("b", 2);
        c.insert("c", 3);
        // Touch "a": now "b" is least recent.
        assert!(c.get(&"a").is_some());
        let evicted = c.insert("d", 4);
        assert_eq!(evicted, Some(("b", 2)));
        assert_eq!(c.keys_by_recency(), vec!["d", "a", "c"]);
        assert_eq!(c.counters().evictions, 1);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn eviction_order_is_insertion_order_without_gets() {
        let mut c: Lru<u64, u64> = Lru::new(2);
        // Keys chosen to collide/disorder under typical hashing; the list,
        // not the hash, must define eviction order.
        c.insert(0xDEAD_BEEF, 1);
        c.insert(0x0000_0001, 2);
        assert_eq!(c.insert(0xFFFF_FFFF, 3),
                   Some((0xDEAD_BEEF, 1)));
        assert_eq!(c.insert(0x1234_5678, 4),
                   Some((0x0000_0001, 2)));
    }

    #[test]
    fn reinsert_refreshes_value_and_recency() {
        let mut c: Lru<u8, u8> = Lru::new(2);
        c.insert(1, 1);
        c.insert(2, 2);
        c.insert(1, 9); // refresh, no eviction
        assert_eq!(c.counters().evictions, 0);
        assert_eq!(c.insert(3, 3), Some((2, 2)));
        assert_eq!(c.peek(&1), Some(&9));
    }

    #[test]
    fn peek_does_not_touch_counters_or_recency() {
        let mut c: Lru<u8, u8> = Lru::new(2);
        c.insert(1, 1);
        c.insert(2, 2);
        assert_eq!(c.peek(&1), Some(&1));
        assert_eq!(c.counters().lookups(), 0);
        // "1" was peeked, not promoted: still the eviction victim.
        assert_eq!(c.insert(3, 3), Some((1, 1)));
    }

    #[test]
    fn capacity_one_always_replaces() {
        let mut c: Lru<u8, u8> = Lru::new(1);
        assert_eq!(c.insert(1, 1), None);
        assert_eq!(c.insert(2, 2), Some((1, 1)));
        assert_eq!(c.insert(3, 3), Some((2, 2)));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn slab_slots_are_reused() {
        let mut c: Lru<u32, u32> = Lru::new(2);
        for i in 0..100 {
            c.insert(i, i);
        }
        // 100 inserts through a capacity-2 cache must not grow the slab
        // beyond capacity.
        assert!(c.nodes.len() <= 2 + 1);
        assert_eq!(c.counters().evictions, 98);
    }

    #[test]
    fn clear_empties_but_keeps_counters() {
        let mut c: Lru<u8, u8> = Lru::new(2);
        c.insert(1, 1);
        assert!(c.get(&1).is_some());
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(&1), None);
        let ctr = c.counters();
        assert_eq!((ctr.hits, ctr.misses, ctr.evictions), (1, 1, 0));
        // Reusable after clearing.
        c.insert(2, 2);
        assert_eq!(c.peek(&2), Some(&2));
    }

    #[test]
    fn merged_counters_sum() {
        let a = CacheCounters { hits: 1, misses: 2, evictions: 3 };
        let b = CacheCounters { hits: 10, misses: 20, evictions: 30 };
        let m = a.merged(&b);
        assert_eq!((m.hits, m.misses, m.evictions), (11, 22, 33));
        let over = CacheCounters::merged_over([a, b, m]);
        assert_eq!((over.hits, over.misses, over.evictions), (22, 44, 66));
        assert_eq!(CacheCounters::merged_over(std::iter::empty()),
                   CacheCounters::default());
    }
}
