//! Statistics helpers for evaluation and benchmarking: summary statistics,
//! percentiles, and cumulative-frequency curves (the paper reports its
//! headline accuracy as a median + CDF, Figs 15 & 17).

/// Summary of a sample: mean / median / stddev / min / max / count.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Drop NaNs (either sign — the hardware's own 0.0/0.0 is a *negative*
/// NaN) and sort what remains.  Order statistics are computed over the
/// finite part of a sample: a stray NaN upstream must neither panic the
/// sort (the old `partial_cmp().unwrap()` did) nor displace or poison
/// the finite quantiles.  Moment statistics (mean/std) intentionally
/// keep IEEE propagation so bad data stays visible.
fn finite_sorted(xs: &[f64]) -> Vec<f64> {
    let mut sorted: Vec<f64> =
        xs.iter().copied().filter(|v| !v.is_nan()).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let sorted = finite_sorted(xs);
        let (median, min, max) = if sorted.is_empty() {
            (f64::NAN, f64::NAN, f64::NAN)
        } else {
            (
                percentile_sorted(&sorted, 50.0),
                sorted[0],
                sorted[sorted.len() - 1],
            )
        };
        Summary {
            n,
            mean,
            median,
            std: var.sqrt(),
            min,
            max,
        }
    }
}

/// Percentile (linear interpolation) of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile of an unsorted sample (over its finite part; NaN if none).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let sorted = finite_sorted(xs);
    if sorted.is_empty() {
        return f64::NAN;
    }
    percentile_sorted(&sorted, p)
}

/// A cumulative-frequency curve: for each x, the fraction of samples <= x.
/// Mirrors the presentation of the paper's Figs 15 and 17.
#[derive(Clone, Debug)]
pub struct Cdf {
    /// Sorted sample values.
    pub xs: Vec<f64>,
}

impl Cdf {
    /// Build the curve over the finite part of the sample (NaNs dropped).
    pub fn of(xs: &[f64]) -> Cdf {
        Cdf {
            xs: finite_sorted(xs),
        }
    }

    /// Fraction of samples `<= x`.
    pub fn at(&self, x: f64) -> f64 {
        // partition_point: number of samples <= x.
        let k = self.xs.partition_point(|&v| v <= x);
        k as f64 / self.xs.len() as f64
    }

    /// Value at quantile `q` in `[0, 1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        percentile_sorted(&self.xs, q * 100.0)
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    /// Evaluate the curve at `k` evenly spaced thresholds covering the
    /// sample range; returns `(threshold, fraction)` pairs for plotting.
    pub fn curve(&self, k: usize) -> Vec<(f64, f64)> {
        assert!(k >= 2);
        let lo = self.xs[0];
        let hi = self.xs[self.xs.len() - 1];
        (0..k)
            .map(|i| {
                let x = lo + (hi - lo) * i as f64 / (k - 1) as f64;
                (x, self.at(x))
            })
            .collect()
    }
}

/// Weighted mean.
pub fn weighted_mean(values: &[f64], weights: &[f64]) -> f64 {
    assert_eq!(values.len(), weights.len());
    let wsum: f64 = weights.iter().sum();
    assert!(wsum > 0.0);
    values
        .iter()
        .zip(weights)
        .map(|(v, w)| v * w)
        .sum::<f64>()
        / wsum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_even_sample_interpolates() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 10.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn percentiles() {
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 25.0), 1.0);
        assert_eq!(percentile(&xs, 12.5), 0.5);
    }

    #[test]
    fn cdf_step_values() {
        let c = Cdf::of(&[1.0, 2.0, 2.0, 4.0]);
        assert_eq!(c.at(0.5), 0.0);
        assert_eq!(c.at(1.0), 0.25);
        assert_eq!(c.at(2.0), 0.75);
        assert_eq!(c.at(3.9), 0.75);
        assert_eq!(c.at(4.0), 1.0);
        assert_eq!(c.median(), 2.0);
    }

    #[test]
    fn cdf_curve_is_monotone() {
        let c = Cdf::of(&[3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]);
        let curve = c.curve(16);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1);
            assert!(w[1].0 > w[0].0);
        }
        assert_eq!(curve.last().unwrap().1, 1.0);
    }

    #[test]
    fn weighted_mean_basics() {
        assert_eq!(weighted_mean(&[1.0, 3.0], &[1.0, 1.0]), 2.0);
        assert_eq!(weighted_mean(&[1.0, 3.0], &[3.0, 1.0]), 1.5);
    }

    #[test]
    #[should_panic]
    fn summary_rejects_empty() {
        Summary::of(&[]);
    }

    #[test]
    fn summary_survives_nan() {
        // Regression: sort_by(partial_cmp().unwrap()) used to panic on any
        // NaN in the sample.  Order statistics now cover the finite part
        // (for NaNs of either sign — the hardware's own 0.0/0.0 is a
        // *negative* NaN); mean keeps IEEE propagation as the bad-data
        // signal.
        for nan in [f64::NAN, -f64::NAN] {
            let s = Summary::of(&[1.0, nan, 2.0]);
            assert_eq!(s.n, 3);
            assert_eq!(s.min, 1.0);
            assert_eq!(s.median, 1.5);
            assert_eq!(s.max, 2.0);
            assert!(s.mean.is_nan());
        }
    }

    #[test]
    fn summary_of_all_nan_is_nan_not_panic() {
        let s = Summary::of(&[f64::NAN, -f64::NAN]);
        assert_eq!(s.n, 2);
        assert!(s.median.is_nan());
        assert!(s.min.is_nan());
        assert!(s.max.is_nan());
    }

    #[test]
    fn summary_survives_single_element_adjacent_to_empty() {
        let s = Summary::of(&[4.5]);
        assert_eq!(s.n, 1);
        assert_eq!(s.median, 4.5);
        assert_eq!(s.min, 4.5);
        assert_eq!(s.max, 4.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_and_cdf_survive_nan() {
        // Must not panic; quantiles cover the finite part only, so a NaN
        // adjacent to the interpolation window cannot leak into a result.
        assert_eq!(percentile(&[f64::NAN, 1.0, 3.0], 100.0), 3.0);
        assert_eq!(percentile(&[1.0, f64::NAN], 50.0), 1.0);
        assert_eq!(percentile(&[-f64::NAN, 1.0, 3.0], 50.0), 2.0);
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
        let c = Cdf::of(&[-f64::NAN, 0.0, 2.0]);
        assert_eq!(c.at(1.0), 0.5);
        assert_eq!(c.median(), 1.0);
    }
}
