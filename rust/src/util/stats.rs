//! Statistics helpers for evaluation and benchmarking: summary statistics,
//! percentiles, and cumulative-frequency curves (the paper reports its
//! headline accuracy as a median + CDF, Figs 15 & 17).

/// Summary of a sample: mean / median / stddev / min / max / count.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            median: percentile_sorted(&sorted, 50.0),
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
        }
    }
}

/// Percentile (linear interpolation) of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile of an unsorted sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// A cumulative-frequency curve: for each x, the fraction of samples <= x.
/// Mirrors the presentation of the paper's Figs 15 and 17.
#[derive(Clone, Debug)]
pub struct Cdf {
    /// Sorted sample values.
    pub xs: Vec<f64>,
}

impl Cdf {
    pub fn of(xs: &[f64]) -> Cdf {
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Cdf { xs: sorted }
    }

    /// Fraction of samples `<= x`.
    pub fn at(&self, x: f64) -> f64 {
        // partition_point: number of samples <= x.
        let k = self.xs.partition_point(|&v| v <= x);
        k as f64 / self.xs.len() as f64
    }

    /// Value at quantile `q` in `[0, 1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        percentile_sorted(&self.xs, q * 100.0)
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    /// Evaluate the curve at `k` evenly spaced thresholds covering the
    /// sample range; returns `(threshold, fraction)` pairs for plotting.
    pub fn curve(&self, k: usize) -> Vec<(f64, f64)> {
        assert!(k >= 2);
        let lo = self.xs[0];
        let hi = self.xs[self.xs.len() - 1];
        (0..k)
            .map(|i| {
                let x = lo + (hi - lo) * i as f64 / (k - 1) as f64;
                (x, self.at(x))
            })
            .collect()
    }
}

/// Weighted mean.
pub fn weighted_mean(values: &[f64], weights: &[f64]) -> f64 {
    assert_eq!(values.len(), weights.len());
    let wsum: f64 = weights.iter().sum();
    assert!(wsum > 0.0);
    values
        .iter()
        .zip(weights)
        .map(|(v, w)| v * w)
        .sum::<f64>()
        / wsum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_even_sample_interpolates() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 10.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn percentiles() {
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 25.0), 1.0);
        assert_eq!(percentile(&xs, 12.5), 0.5);
    }

    #[test]
    fn cdf_step_values() {
        let c = Cdf::of(&[1.0, 2.0, 2.0, 4.0]);
        assert_eq!(c.at(0.5), 0.0);
        assert_eq!(c.at(1.0), 0.25);
        assert_eq!(c.at(2.0), 0.75);
        assert_eq!(c.at(3.9), 0.75);
        assert_eq!(c.at(4.0), 1.0);
        assert_eq!(c.median(), 2.0);
    }

    #[test]
    fn cdf_curve_is_monotone() {
        let c = Cdf::of(&[3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]);
        let curve = c.curve(16);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1);
            assert!(w[1].0 > w[0].0);
        }
        assert_eq!(curve.last().unwrap().1, 1.0);
    }

    #[test]
    fn weighted_mean_basics() {
        assert_eq!(weighted_mean(&[1.0, 3.0], &[1.0, 1.0]), 2.0);
        assert_eq!(weighted_mean(&[1.0, 3.0], &[3.0, 1.0]), 1.5);
    }

    #[test]
    #[should_panic]
    fn summary_rejects_empty() {
        Summary::of(&[]);
    }
}
