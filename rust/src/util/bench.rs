//! Micro/meso benchmark harness (criterion substitute — criterion is not in
//! the offline vendor set).
//!
//! Usage pattern inside a `harness = false` bench binary:
//!
//! ```ignore
//! let mut h = bench::Harness::new("fig17_accuracy");
//! h.bench("fit_batch_64", || coordinator.fit_batch(&runs));
//! h.report();
//! ```
//!
//! Each case is warmed up, then timed over adaptively-chosen iteration
//! batches until the target measurement time is reached; mean / median /
//! stddev / min are reported, and results can be dumped as JSON for the
//! EXPERIMENTS.md records.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Summary;

/// One measured benchmark case.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub name: String,
    /// Per-iteration wall time, seconds, one entry per measured batch.
    pub samples: Vec<f64>,
    pub iters_per_sample: u64,
    pub summary: Summary,
}

impl CaseResult {
    pub fn to_json(&self) -> Json {
        Json::from_pairs([
            ("name", Json::Str(self.name.clone())),
            ("iters_per_sample", Json::Num(self.iters_per_sample as f64)),
            ("mean_s", Json::Num(self.summary.mean)),
            ("median_s", Json::Num(self.summary.median)),
            ("std_s", Json::Num(self.summary.std)),
            ("min_s", Json::Num(self.summary.min)),
            ("samples", Json::from_f64_slice(&self.samples)),
        ])
    }
}

/// Benchmark harness configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
        }
    }
}

pub struct Harness {
    pub group: String,
    pub config: Config,
    pub results: Vec<CaseResult>,
    quiet: bool,
}

impl Harness {
    pub fn new(group: &str) -> Harness {
        Harness {
            group: group.to_string(),
            config: Config::default(),
            results: Vec::new(),
            quiet: false,
        }
    }

    pub fn with_config(group: &str, config: Config) -> Harness {
        Harness {
            group: group.to_string(),
            config,
            results: Vec::new(),
            quiet: false,
        }
    }

    pub fn quiet(mut self) -> Harness {
        self.quiet = true;
        self
    }

    /// Time `f`, returning (and recording) the per-iteration statistics.
    /// The closure's return value is black-boxed to keep the optimizer
    /// honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F)
        -> &CaseResult {
        // Warmup + estimate cost of one iteration.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.config.warmup || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

        // Choose a batch size so each sample is ~ measure/min_samples.
        let target_sample = self.config.measure.as_secs_f64()
            / self.config.min_samples as f64;
        let iters = ((target_sample / per_iter.max(1e-12)) as u64).max(1);

        let mut samples = Vec::new();
        let measure_start = Instant::now();
        while samples.len() < self.config.min_samples
            || measure_start.elapsed() < self.config.measure
        {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
            if samples.len() >= 1000 {
                break;
            }
        }

        let result = CaseResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
            samples,
            iters_per_sample: iters,
        };
        if !self.quiet {
            println!(
                "{:40} {:>12}/iter (median; mean {}, n={}x{})",
                format!("{}/{}", self.group, name),
                fmt_duration(result.summary.median),
                fmt_duration(result.summary.mean),
                result.samples.len(),
                iters
            );
        }
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print a closing summary table.
    pub fn report(&self) {
        if self.quiet {
            return;
        }
        println!("\n== {} ==", self.group);
        println!("{:<40} {:>12} {:>12} {:>12}", "case", "median", "mean",
                 "min");
        for r in &self.results {
            println!(
                "{:<40} {:>12} {:>12} {:>12}",
                r.name,
                fmt_duration(r.summary.median),
                fmt_duration(r.summary.mean),
                fmt_duration(r.summary.min)
            );
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs([
            ("group", Json::Str(self.group.clone())),
            (
                "cases",
                Json::Arr(self.results.iter().map(CaseResult::to_json)
                    .collect()),
            ),
        ])
    }
}

/// Pretty-print a duration in seconds with an adaptive unit.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Optimization barrier (std::hint::black_box is stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_config() -> Config {
        Config {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_samples: 3,
        }
    }

    #[test]
    fn measures_cheap_closure() {
        let mut h = Harness::with_config("t", fast_config()).quiet();
        let mut acc = 0u64;
        let r = h.bench("add", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert!(r.summary.median > 0.0);
        assert!(r.summary.median < 1e-3, "1 add should be < 1ms");
        assert!(r.samples.len() >= 3);
    }

    #[test]
    fn ordering_reflects_cost() {
        let mut h = Harness::with_config("t", fast_config()).quiet();
        let cheap = h.bench("cheap", || 1 + 1).summary.median;
        let costly = h
            .bench("costly", || (0..20_000).map(black_box).sum::<usize>())
            .summary
            .median;
        assert!(costly > cheap * 5.0, "costly={costly} cheap={cheap}");
    }

    #[test]
    fn json_dump_has_cases() {
        let mut h = Harness::with_config("grp", fast_config()).quiet();
        h.bench("a", || 0);
        let j = h.to_json();
        assert_eq!(j.get("group").unwrap().as_str().unwrap(), "grp");
        assert_eq!(j.get("cases").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.0), "2.000 s");
        assert_eq!(fmt_duration(2e-3), "2.000 ms");
        assert_eq!(fmt_duration(2e-6), "2.000 µs");
        assert_eq!(fmt_duration(2e-9), "2.0 ns");
    }
}
