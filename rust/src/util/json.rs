//! Minimal JSON encode/decode substrate (serde is not in the offline
//! vendor set, and the facade crate is absent even though serde_derive is).
//!
//! Supports the full JSON data model; parsing is recursive descent with a
//! depth limit.  Used for: the AOT `manifest.json`, machine topology config
//! files, signature stores, and result dumps consumed by the benches.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Numbers are kept as `f64` (all our payloads are counters,
/// fractions, and shapes — comfortably inside the 2^53 integer range), with
/// an exact `Int` escape hatch for u64 counters that exceed 2^53 (a lifetime
/// byte counter can: `(1<<53) as f64` silently rounds).  `Int` is only ever
/// produced for values where the f64 path would lose precision, so the two
/// spellings never alias for small integers.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs<I: IntoIterator<Item = (&'static str, Json)>>(
        pairs: I,
    ) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Exact u64 counter.  Values at or below 2^53 use the `Num` spelling
    /// (identical bytes on the wire, and `==` keeps working against parsed
    /// replies); larger values use the lossless `Int` spelling.
    pub fn from_u64(n: u64) -> Json {
        if n <= MAX_SAFE_F64_INT {
            Json::Num(n as f64)
        } else {
            Json::Int(n)
        }
    }

    // ---- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panic-free typed getters.  `Int` answers as `f64` too (lossy above
    /// 2^53) so numeric call sites need not care which spelling arrived.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Int(n) => Some(*n as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Exact u64 view: `Int` verbatim, `Num` when it is a non-negative
    /// integer inside the safe range.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Int(n) => Some(*n),
            Json::Num(n)
                if n.fract() == 0.0
                    && *n >= 0.0
                    && *n <= MAX_SAFE_F64_INT as f64 =>
            {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        }
    }

    // ---- encoding ----------------------------------------------------------

    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 9e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Int(n) => out.push_str(&format!("{n}")),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- decoding ----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

const MAX_DEPTH: usize = 128;

/// Largest integer such that every non-negative integer up to it maps to a
/// distinct f64 (2^53).
const MAX_SAFE_F64_INT: u64 = 1 << 53;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    /// Four hex digits starting at byte `start` (the `\uXXXX` payload).
    fn hex4(&self, start: usize) -> Result<u32, JsonError> {
        if start + 4 > self.bytes.len() {
            return Err(self.err("bad \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[start..start + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        u32::from_str_radix(hex, 16)
            .map_err(|_| self.err("bad \\u escape"))
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        // Unsigned integer literals above 2^53 take the exact path: the f64
        // representation would round them, so `parse -> encode` would change
        // the bytes of a large counter.  Everything else (small integers
        // included) keeps the historical `Num` spelling.
        if !s.starts_with('-')
            && s.bytes().all(|b| b.is_ascii_digit())
        {
            if let Ok(n) = s.parse::<u64>() {
                if n > MAX_SAFE_F64_INT {
                    return Ok(Json::Int(n));
                }
            }
        }
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hi = self.hex4(self.pos + 1)?;
                            self.pos += 4;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // High surrogate: a valid escaped
                                // UTF-16 pair (e.g. \ud83d\ude00 =
                                // U+1F600) decodes to one scalar;
                                // anything else is malformed.
                                if self.bytes.get(self.pos + 1)
                                    != Some(&b'\\')
                                    || self.bytes.get(self.pos + 2)
                                        != Some(&b'u')
                                {
                                    return Err(self.err(
                                        "lone high surrogate (expected \
                                         \\u low surrogate)",
                                    ));
                                }
                                let lo = self.hex4(self.pos + 3)?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err(
                                        "invalid low surrogate in \\u \
                                         pair",
                                    ));
                                }
                                self.pos += 6;
                                0x10000
                                    + ((hi - 0xD800) << 10)
                                    + (lo - 0xDC00)
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return Err(
                                    self.err("lone low surrogate")
                                );
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.depth += 1;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.depth += 1;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e3",
                     "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.encode()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn parses_nested_structure() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": -2.5e-2}"#,
        )
        .unwrap();
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), -0.025);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "[1] x",
                     "\"unterminated"] {
            assert!(Json::parse(text).is_err(), "{text:?} should fail");
        }
    }

    #[test]
    fn encode_escapes_strings() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(v.encode(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::Num(64.0).encode(), "64");
        assert_eq!(Json::Num(2.5).encode(), "2.5");
    }

    #[test]
    fn large_counters_roundtrip_byte_exactly() {
        // Regression: (2^53 + 1) as f64 rounds to 2^53, so the Num path
        // silently decremented any odd counter above the safe range.
        let odd = (1u64 << 53) + 1;
        assert_ne!((odd as f64) as u64, odd, "f64 path must be lossy here");
        for n in [odd, u64::MAX, u64::MAX - 1, (1u64 << 60) + 7] {
            let text = n.to_string();
            let v = Json::parse(&text).unwrap();
            assert_eq!(v, Json::Int(n), "{n}");
            assert_eq!(v.encode(), text, "byte-exact round-trip for {n}");
            assert_eq!(v.as_u64(), Some(n));
            assert_eq!(Json::from_u64(n), Json::Int(n));
        }
        // Exact integers inside the safe range keep the historical Num
        // spelling so equality against parsed replies still holds.
        for n in [0u64, 1, 64, (1 << 53) - 1, 1 << 53] {
            assert_eq!(Json::from_u64(n), Json::Num(n as f64), "{n}");
            assert_eq!(Json::parse(&n.to_string()).unwrap(),
                       Json::Num(n as f64));
            assert_eq!(Json::from_u64(n).encode(), n.to_string());
            assert_eq!(Json::Num(n as f64).as_u64(), Some(n));
        }
        // Negative and fractional literals never take the Int path.
        assert_eq!(Json::parse("-9007199254740993").unwrap(),
                   Json::Num(-9007199254740993i64 as f64));
        assert_eq!(Json::parse("2.5").unwrap(), Json::Num(2.5));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(2.5).as_u64(), None);
        // Int answers the lossy f64 view too.
        assert_eq!(Json::Int(odd).as_f64(), Some(odd as f64));
    }

    #[test]
    fn roundtrip_random_structures() {
        // Poor man's property test against the encoder.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let v = random_json(&mut rng, 0);
            let text = v.encode();
            let back = Json::parse(&text).unwrap();
            assert_eq!(v, back);
        }
    }

    fn random_json(rng: &mut crate::util::rng::Rng, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(1000) as f64) / 8.0),
            3 => Json::Str(format!("s{}\n\"x", rng.below(100))),
            4 => Json::Arr(
                (0..rng.below(4)).map(|_| random_json(rng, depth + 1)).collect(),
            ),
            _ => {
                let mut m = BTreeMap::new();
                for i in 0..rng.below(4) {
                    m.insert(format!("k{i}"), random_json(rng, depth + 1));
                }
                Json::Obj(m)
            }
        }
    }

    #[test]
    fn decodes_utf16_surrogate_pairs() {
        // Regression: a client payload carrying an escaped non-BMP
        // scalar (e.g. an emoji in a workload name) was a per-request
        // "bad codepoint" error.
        let v = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
        // Pair inside surrounding text, and BMP escapes unaffected.
        let v = Json::parse(r#""a\ud83d\ude00b\u00e9""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\u{1F600}b\u{e9}");
        // Lowest/highest representable pairs.
        let v = Json::parse(r#""\ud800\udc00 \udbff\udfff""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{10000} \u{10FFFF}");
    }

    #[test]
    fn rejects_lone_and_malformed_surrogates() {
        for bad in [
            r#""\ud83d""#,            // lone high at end of string
            r#""\ud83dx""#,           // high followed by a raw char
            r#""\ud83d\n""#,          // high followed by another escape
            r#""\ud83d\u0041""#,      // high + a non-surrogate escape
            r#""\ude00""#,            // lone low
            r#""\ud83d\ud83d""#,      // high followed by another high
        ] {
            let err = Json::parse(bad).unwrap_err();
            assert!(format!("{err}").contains("surrogate"),
                    "{bad}: {err}");
        }
    }

    #[test]
    fn surrogate_pair_roundtrips_through_encode() {
        // Encode writes raw UTF-8 for printable scalars; the decoder
        // must accept both the raw and the escaped spelling and agree.
        let v = Json::Str("numa \u{1F600}\u{10FFFF} bw".to_string());
        let encoded = v.encode();
        assert_eq!(Json::parse(&encoded).unwrap(), v);
        let mut obj = Json::obj();
        obj.set("name", v.clone());
        let back = Json::parse(&obj.encode()).unwrap();
        assert_eq!(back.get("name"), Some(&v));
        // Escaped spelling decodes to the same value the raw round-trip
        // produced.
        let escaped =
            r#"{"name":"numa \ud83d\ude00\udbff\udfff bw"}"#;
        assert_eq!(Json::parse(escaped).unwrap().get("name"), Some(&v));
    }

    #[test]
    fn parses_aot_manifest_shape() {
        let text = r#"{
          "batch": 64, "sockets": 2,
          "pipelines": {"fit_signature": {"file": "fit_signature.hlo.txt",
            "args": [[64,2,2],[64,2]], "results": [[64,3]], "hlo_bytes": 10}}
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("batch").unwrap().as_usize().unwrap(), 64);
        let p = v.get("pipelines").unwrap().get("fit_signature").unwrap();
        assert_eq!(
            p.get("args").unwrap().as_arr().unwrap()[0].as_f64_vec().unwrap(),
            vec![64.0, 2.0, 2.0]
        );
    }
}
