//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` style used by the `numabw` binary.  Unknown flags are errors —
//! a typo silently ignored in an experiment driver costs an afternoon.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    /// `--key value` and `--key=value` pairs; bare `--key` maps to "true".
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse a raw token list (usually `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let tokens: Vec<String> = tokens.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len()
                    && !tokens[i + 1].starts_with("--")
                {
                    out.flags
                        .insert(stripped.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}"))
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}"))
            })
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error out on flags not in the allow-list (typo protection).
    pub fn ensure_known(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "unknown flag --{k}; expected one of: {}",
                    allowed
                        .iter()
                        .map(|a| format!("--{a}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(toks("evaluate --machine xeon18 --seed=7 pos1"));
        assert_eq!(a.command.as_deref(), Some("evaluate"));
        assert_eq!(a.get("machine"), Some("xeon18"));
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn bare_flag_is_boolean() {
        let a = Args::parse(toks("run --verbose --out x.json"));
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn trailing_bare_flag() {
        let a = Args::parse(toks("run --quiet"));
        assert!(a.get_bool("quiet"));
    }

    #[test]
    fn typed_getters_with_defaults() {
        let a = Args::parse(toks("x --n 5 --rate 0.5"));
        assert_eq!(a.get_usize("n", 1), 5);
        assert_eq!(a.get_usize("missing", 9), 9);
        assert_eq!(a.get_f64("rate", 0.0), 0.5);
    }

    #[test]
    #[should_panic]
    fn typed_getter_rejects_garbage() {
        Args::parse(toks("x --n five")).get_usize("n", 0);
    }

    #[test]
    fn unknown_flags_flagged() {
        let a = Args::parse(toks("x --good 1 --bda 2"));
        assert!(a.ensure_known(&["good", "bad"]).is_err());
        assert!(a.ensure_known(&["good", "bda"]).is_ok());
    }
}
