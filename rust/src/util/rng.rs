//! Deterministic PRNG substrate (no `rand` crate in the offline vendor set).
//!
//! `SplitMix64` seeds `Xoshiro256StarStar`, the standard pairing recommended
//! by the xoshiro authors.  Every stochastic component of the simulator
//! (counter jitter, QPI background noise, workload heterogeneity) draws from
//! an explicitly-seeded stream so simulator runs are exactly reproducible —
//! a requirement for the paper-figure benches to be stable across runs.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams for all practical purposes.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (used to give each simulated
    /// thread / epoch its own stream without sequencing artifacts).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (unbiased via rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here — noise generation is far off the simulator's hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Gaussian with mean `mu` and standard deviation `sigma`.
    pub fn gaussian(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Multiplicative jitter: `1 + N(0, sigma)`, clamped positive.
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        (1.0 + sigma * self.normal()).max(1e-6)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_vectors() {
        // Reference values for seed 1234567 (from the published algorithm).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn forked_streams_are_independent_of_parent_consumption() {
        let mut parent = Rng::new(7);
        let mut child = parent.fork(1);
        let v1 = child.next_u64();
        // Forking again with a different tag gives a different stream.
        let mut child2 = parent.fork(2);
        assert_ne!(v1, child2.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.uniform(2.0, 5.0);
            assert!((2.0..5.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn jitter_stays_positive() {
        let mut r = Rng::new(8);
        for _ in 0..10_000 {
            assert!(r.jitter(0.5) > 0.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
