//! Measurement and machine noise (paper §2.1.1, §6.1).
//!
//! Three effects the real testbed exhibits and the model has to survive:
//!
//! 1. **Counter jitter** — uncore counters are sampled, not transactional;
//!    consecutive identical runs differ by a fraction of a percent.  (The
//!    paper's Fig 12 attributes its <0.9 % synthetic miscategorisation to
//!    exactly this background noise.)
//! 2. **QPI background traffic** — §2.1.1: the interconnect carries
//!    substantial non-application traffic (snoops, prefetch, kernel).  The
//!    paper found the QPI *counters* unusable for modeling; here that
//!    traffic instead shaves a stochastic few percent off the usable link
//!    capacity, as it does on silicon.
//! 3. **Execution-rate wobble** — per-socket instruction rates drift with
//!    frequency scaling; a small multiplicative jitter on retired
//!    instructions models it (the §5.2 normalization must absorb it).

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NoiseConfig {
    /// σ of the multiplicative jitter applied to every counter reading.
    pub counter_sigma: f64,
    /// Mean fraction of QPI capacity consumed by background traffic.
    pub qpi_background: f64,
    /// σ of the QPI background fraction (per epoch).
    pub qpi_sigma: f64,
    /// σ of the per-socket instruction-rate jitter.
    pub rate_sigma: f64,
    /// Mean *absolute* background traffic per bank counter component
    /// (bytes/s): kernel threads, prefetcher junk, daemons.  Scale-free
    /// multiplicative jitter cannot reproduce Fig 18's shape — on real
    /// machines the noise floor is absolute, so benchmarks that move
    /// little data (ep, art) see proportionally larger distortion.
    pub background_bw: f64,
}

impl NoiseConfig {
    /// Calibrated default: sub-percent counter noise, a few percent of QPI
    /// lost to background traffic.
    pub fn realistic() -> NoiseConfig {
        NoiseConfig {
            counter_sigma: 0.004,
            qpi_background: 0.03,
            qpi_sigma: 0.01,
            rate_sigma: 0.008,
            background_bw: 12.0e6, // ~6 MB/s per bank counter component
        }
    }

    /// Noise-free — for unit tests that need exact counter inversion.
    pub fn none() -> NoiseConfig {
        NoiseConfig {
            counter_sigma: 0.0,
            qpi_background: 0.0,
            qpi_sigma: 0.0,
            rate_sigma: 0.0,
            background_bw: 0.0,
        }
    }

    pub fn is_none(&self) -> bool {
        *self == Self::none()
    }

    /// Jitter one counter reading.
    pub fn jitter_counter(&self, rng: &mut Rng, value: f64) -> f64 {
        if self.counter_sigma == 0.0 {
            value
        } else {
            value * rng.jitter(self.counter_sigma)
        }
    }

    /// Effective QPI capacity after background traffic, this epoch.
    pub fn degrade_qpi(&self, rng: &mut Rng, cap: f64) -> f64 {
        if self.qpi_background == 0.0 && self.qpi_sigma == 0.0 {
            return cap;
        }
        let frac = (self.qpi_background + self.qpi_sigma * rng.normal())
            .clamp(0.0, 0.5);
        cap * (1.0 - frac)
    }

    /// Per-socket instruction-rate multiplier, this epoch.
    pub fn rate_multiplier(&self, rng: &mut Rng) -> f64 {
        if self.rate_sigma == 0.0 {
            1.0
        } else {
            rng.jitter(self.rate_sigma)
        }
    }

    /// Background bytes accumulated by one counter component over `dt`
    /// seconds (uniform in `[0, 2*mean]` — bursty, always non-negative).
    pub fn background_bytes(&self, rng: &mut Rng, dt: f64) -> f64 {
        if self.background_bw == 0.0 {
            0.0
        } else {
            rng.uniform(0.0, 2.0 * self.background_bw) * dt
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_identity() {
        let n = NoiseConfig::none();
        let mut rng = Rng::new(1);
        assert_eq!(n.jitter_counter(&mut rng, 5.0), 5.0);
        assert_eq!(n.degrade_qpi(&mut rng, 10.0), 10.0);
        assert_eq!(n.rate_multiplier(&mut rng), 1.0);
        assert!(n.is_none());
    }

    #[test]
    fn counter_jitter_is_small_and_unbiased() {
        let n = NoiseConfig::realistic();
        let mut rng = Rng::new(2);
        let k = 20_000;
        let mean: f64 = (0..k)
            .map(|_| n.jitter_counter(&mut rng, 1.0))
            .sum::<f64>()
            / k as f64;
        assert!((mean - 1.0).abs() < 0.001, "mean={mean}");
    }

    #[test]
    fn qpi_degradation_bounded() {
        let n = NoiseConfig::realistic();
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let c = n.degrade_qpi(&mut rng, 100.0);
            assert!(c <= 100.0 && c >= 50.0);
        }
    }

    #[test]
    fn qpi_mean_loss_matches_background() {
        let n = NoiseConfig::realistic();
        let mut rng = Rng::new(4);
        let k = 20_000;
        let mean: f64 = (0..k)
            .map(|_| n.degrade_qpi(&mut rng, 1.0))
            .sum::<f64>()
            / k as f64;
        assert!((mean - 0.97).abs() < 0.003, "mean={mean}");
    }
}
