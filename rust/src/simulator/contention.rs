//! Bounded max-min fair bandwidth allocation — the Rust reference
//! implementation of the contention model (the Pallas `maxmin` kernel is
//! the batched HLO twin; `python/tests/test_maxmin.py` pins both to a third
//! exact implementation).
//!
//! The simulated machine arbitrates per-request at every memory channel and
//! interconnect link, which in steady state approximates max-min fairness
//! across the competing flows: every flow ramps until it is satisfied or
//! some resource on its path saturates (progressive water-filling).

/// A flow: a demand (bytes/s) across a set of resources.
#[derive(Clone, Debug)]
pub struct Flow {
    pub demand: f64,
    /// Resource indices this flow consumes (1 or 2 in our topologies:
    /// a memory channel, plus an interconnect link if remote).
    pub resources: Vec<usize>,
}

impl Flow {
    pub fn new(demand: f64, resources: &[usize]) -> Flow {
        Flow {
            demand,
            resources: resources.to_vec(),
        }
    }
}

/// Relative saturation tolerance: a resource whose residual is below
/// `SAT_TOL * cap` is considered saturated.
const SAT_TOL: f64 = 1e-9;

/// Reusable workspace for [`maxmin_into`]: lets the simulator's epoch loop
/// resolve contention thousands of times without allocating.
#[derive(Default, Clone, Debug)]
pub struct MaxminScratch {
    frozen: Vec<bool>,
    residual: Vec<f64>,
    counts: Vec<u32>,
    sat: Vec<bool>,
}

/// Exact progressive-filling max-min allocation.
///
/// Invariants on the result (tested below):
///   * `alloc[f] <= flows[f].demand`
///   * per-resource load `<= cap`
///   * max-min optimality: no flow can gain without taking from a flow
///     with an equal or smaller allocation.
pub fn maxmin(flows: &[Flow], caps: &[f64]) -> Vec<f64> {
    let demands: Vec<f64> = flows.iter().map(|f| f.demand).collect();
    let resources: Vec<&[usize]> =
        flows.iter().map(|f| f.resources.as_slice()).collect();
    let mut alloc = vec![0.0; flows.len()];
    let mut scratch = MaxminScratch::default();
    maxmin_into(&demands, &resources, caps, &mut alloc, &mut scratch);
    alloc
}

/// Allocation core over parallel arrays (`demands[i]` uses
/// `resources[i]`), writing into `alloc` and reusing `scratch` buffers —
/// the zero-allocation form the simulator's hot loop calls.
pub fn maxmin_into(demands: &[f64], resources: &[&[usize]], caps: &[f64],
                   alloc: &mut [f64], scratch: &mut MaxminScratch) {
    let nf = demands.len();
    let nr = caps.len();
    debug_assert_eq!(resources.len(), nf);
    debug_assert_eq!(alloc.len(), nf);

    scratch.frozen.clear();
    scratch.frozen.resize(nf, false);
    scratch.residual.clear();
    scratch.residual.extend_from_slice(caps);
    scratch.counts.clear();
    scratch.counts.resize(nr, 0);
    scratch.sat.clear();
    scratch.sat.resize(nr, false);
    let frozen = &mut scratch.frozen;
    let residual = &mut scratch.residual;
    let counts = &mut scratch.counts;
    let sat = &mut scratch.sat;

    let mut n_active = 0usize;
    for i in 0..nf {
        debug_assert!(resources[i].iter().all(|&r| r < nr),
                      "flow {i} references missing resource");
        alloc[i] = 0.0;
        if demands[i] <= 0.0 {
            frozen[i] = true;
        } else {
            n_active += 1;
        }
    }

    // Each round saturates >= 1 resource or satisfies >= 1 flow.
    for _round in 0..(nf + nr + 2) {
        if n_active == 0 {
            break;
        }
        // Count active flows per resource.
        for c in counts.iter_mut() {
            *c = 0;
        }
        for i in 0..nf {
            if !frozen[i] {
                for &r in resources[i] {
                    counts[r] += 1;
                }
            }
        }
        // Uniform level increment: the largest step every active flow can
        // take together without oversubscribing any resource.  Flows with
        // less remaining demand take only what they need (and freeze), so
        // each round saturates a resource or satisfies every flow whose
        // remaining demand is below the level — the same semantics as the
        // Pallas kernel, converging in ~#resources rounds instead of one
        // flow-retirement per round.
        let mut level = f64::INFINITY;
        for r in 0..nr {
            if counts[r] > 0 {
                level = level.min(residual[r] / counts[r] as f64);
            }
        }
        if !level.is_finite() {
            // No active flow touches any resource: satisfy them outright.
            for i in 0..nf {
                if !frozen[i] {
                    alloc[i] = demands[i];
                    frozen[i] = true;
                }
            }
            break;
        }
        let level = level.max(0.0);

        // Advance all active flows by min(level, remaining demand).
        for i in 0..nf {
            if frozen[i] {
                continue;
            }
            let grow = level.min(demands[i] - alloc[i]);
            alloc[i] += grow;
            for &r in resources[i] {
                residual[r] -= grow;
            }
        }
        // Freeze satisfied flows and flows crossing saturated resources.
        for r in 0..nr {
            sat[r] = residual[r] <= SAT_TOL * caps[r].max(1.0);
        }
        for i in 0..nf {
            if frozen[i] {
                continue;
            }
            if demands[i] - alloc[i] <= SAT_TOL * demands[i].max(1.0)
                || resources[i].iter().any(|&r| sat[r])
            {
                frozen[i] = true;
                n_active -= 1;
            }
        }
    }
}

/// Convenience: allocation plus per-resource loads.
pub fn maxmin_with_loads(flows: &[Flow], caps: &[f64])
    -> (Vec<f64>, Vec<f64>) {
    let alloc = maxmin(flows, caps);
    let mut loads = vec![0.0; caps.len()];
    for (a, f) in alloc.iter().zip(flows) {
        for &r in &f.resources {
            loads[r] += a;
        }
    }
    (alloc, loads)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(demand: f64, rs: &[usize]) -> Flow {
        Flow::new(demand, rs)
    }

    #[test]
    fn single_bottleneck_fair_split() {
        let alloc = maxmin(&[f(8.0, &[0]), f(3.0, &[0])], &[10.0]);
        assert!((alloc[0] - 7.0).abs() < 1e-9);
        assert!((alloc[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn unconstrained_get_demand() {
        let alloc = maxmin(&[f(5.0, &[0]), f(7.0, &[1])], &[100.0, 100.0]);
        assert_eq!(alloc, vec![5.0, 7.0]);
    }

    #[test]
    fn equal_split_on_saturation() {
        let flows: Vec<Flow> = (0..4).map(|_| f(10.0, &[0])).collect();
        let alloc = maxmin(&flows, &[12.0]);
        for a in alloc {
            assert!((a - 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn cascade_after_freeze() {
        // Flow 0: r0 only, demand 6.  Flow 1: r0+r1, r1 caps it at 2.
        let alloc = maxmin(&[f(6.0, &[0]), f(10.0, &[0, 1])], &[10.0, 2.0]);
        assert!((alloc[0] - 6.0).abs() < 1e-9, "{alloc:?}");
        assert!((alloc[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn two_resource_chain() {
        let alloc = maxmin(&[f(10.0, &[0, 1]), f(10.0, &[1])], &[10.0, 4.0]);
        assert!((alloc[0] - 2.0).abs() < 1e-9);
        assert!((alloc[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_demand_flows_stay_zero() {
        let alloc = maxmin(&[f(0.0, &[0]), f(5.0, &[0])], &[10.0]);
        assert_eq!(alloc, vec![0.0, 5.0]);
    }

    #[test]
    fn feasibility_invariants_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let nr = 2 + rng.below(6) as usize;
            let nf = 1 + rng.below(12) as usize;
            let caps: Vec<f64> =
                (0..nr).map(|_| rng.uniform(5.0, 100.0)).collect();
            let flows: Vec<Flow> = (0..nf)
                .map(|_| {
                    let k = 1 + rng.below(2) as usize;
                    let rs: Vec<usize> = (0..k)
                        .map(|_| rng.below(nr as u64) as usize)
                        .collect();
                    f(rng.uniform(0.0, 80.0), &rs)
                })
                .collect();
            let (alloc, loads) = maxmin_with_loads(&flows, &caps);
            for (a, fl) in alloc.iter().zip(&flows) {
                assert!(*a <= fl.demand + 1e-6);
                assert!(*a >= 0.0);
            }
            for (l, c) in loads.iter().zip(&caps) {
                assert!(*l <= c * (1.0 + 1e-6) + 1e-9, "load {l} cap {c}");
            }
        }
    }

    #[test]
    fn maxmin_optimality_random() {
        // No flow can be below another flow sharing a resource unless it is
        // demand-limited (bounded max-min characterisation).
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let nr = 2 + rng.below(4) as usize;
            let caps: Vec<f64> =
                (0..nr).map(|_| rng.uniform(5.0, 50.0)).collect();
            let flows: Vec<Flow> = (0..6)
                .map(|_| {
                    f(rng.uniform(1.0, 60.0),
                      &[rng.below(nr as u64) as usize])
                })
                .collect();
            let (alloc, loads) = maxmin_with_loads(&flows, &caps);
            for i in 0..flows.len() {
                let demand_limited = alloc[i] >= flows[i].demand - 1e-6;
                if demand_limited {
                    continue;
                }
                // Rate-limited flow: every resource it uses must be
                // saturated, and it must be among the top allocations there.
                for &r in &flows[i].resources {
                    assert!(loads[r] >= caps[r] - 1e-6,
                            "rate-limited flow on unsaturated resource");
                    for j in 0..flows.len() {
                        if flows[j].resources.contains(&r) {
                            assert!(alloc[j] <= alloc[i] + 1e-6
                                    || alloc[j] <= flows[j].demand + 1e-6);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn work_conserving_when_capacity_ample() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(13);
        let flows: Vec<Flow> = (0..8)
            .map(|i| f(rng.uniform(0.1, 1.0), &[i % 4]))
            .collect();
        let alloc = maxmin(&flows, &[100.0; 4]);
        for (a, fl) in alloc.iter().zip(&flows) {
            assert!((a - fl.demand).abs() < 1e-9);
        }
    }
}
