//! The NUMA machine simulator: the substrate standing in for the paper's
//! two Xeon testbeds (repro band 0/5 — no hardware; DESIGN.md §1).
//!
//! Epoch-based steady-state simulation.  Each epoch:
//!
//! 1. every thread's *demand* is computed from its workload mixture
//!    (bank split per §4 semantics, with per-thread data ownership for the
//!    heterogeneous cases) and the latency issue-rate model;
//! 2. demands become flows over memory-channel + interconnect resources and
//!    are resolved by max-min-fair water-filling (contention);
//! 3. achieved traffic is accumulated into the bank-perspective performance
//!    counters, instructions retire in proportion to achieved bytes, and
//!    noise (counter jitter, QPI background, rate wobble) is applied.
//!
//! The paper measures after the application reaches a stable state
//! (autonuma disabled, §6); the simulator *is* the stable state, so a
//! handful of epochs is enough to integrate the noise distribution.

use crate::counters::{Channel, CounterSnapshot, ProfiledRun};
use crate::simulator::contention::{maxmin_into, Flow, MaxminScratch};
use crate::simulator::latency::thread_demand;
use crate::simulator::noise::NoiseConfig;
use crate::simulator::placement::ThreadPlacement;
use crate::topology::MachineTopology;
use crate::util::rng::Rng;
use crate::workloads::WorkloadSpec;

/// Simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Number of steady-state epochs to integrate.
    pub epochs: usize,
    /// Simulated wall-clock seconds per epoch.
    pub epoch_s: f64,
    /// Root seed; every (workload, placement) run derives its own stream.
    pub seed: u64,
    pub noise: NoiseConfig,
    /// Page migration (autonuma).  The paper disables it for all
    /// measurements; the simulator only supports `false` and asserts so —
    /// the flag exists to document the decision.
    pub autonuma: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            epochs: 4,
            epoch_s: 0.25,
            seed: 0x4E554D41, // "NUMA"
            noise: NoiseConfig::realistic(),
            autonuma: false,
        }
    }
}

impl SimConfig {
    pub fn noiseless() -> SimConfig {
        SimConfig {
            noise: NoiseConfig::none(),
            ..SimConfig::default()
        }
    }

    pub fn with_seed(mut self, seed: u64) -> SimConfig {
        self.seed = seed;
        self
    }
}

/// Everything one simulated run produces.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Counter delta + placement — the §5 fit input.
    pub run: ProfiledRun,
    /// Mean achieved bandwidth over the run (bytes/s, all banks).
    pub achieved_bw: f64,
    /// Mean demanded bandwidth (bytes/s) before contention.
    pub demanded_bw: f64,
    /// Mean achieved bandwidth issued by the threads of each socket.
    pub per_socket_bw: Vec<f64>,
}

impl RunResult {
    /// Fraction of demand that was satisfied — the placement-quality /
    /// speed proxy used for the Fig 1 reproduction (for a fixed workload,
    /// work completed scales with bytes traversed).
    pub fn satisfaction(&self) -> f64 {
        if self.demanded_bw > 0.0 {
            self.achieved_bw / self.demanded_bw
        } else {
            1.0
        }
    }
}

/// The simulator: a machine plus run configuration.
#[derive(Clone, Debug)]
pub struct Simulator {
    pub machine: MachineTopology,
    pub config: SimConfig,
}

impl Simulator {
    pub fn new(machine: MachineTopology, config: SimConfig) -> Simulator {
        assert!(!config.autonuma,
                "autonuma must stay disabled (paper §6: measurements are \
                 taken in a stable state)");
        Simulator { machine, config }
    }

    /// Execute `workload` under `placement` and report counters + rates.
    pub fn run(&self, workload: &WorkloadSpec, placement: &ThreadPlacement)
        -> RunResult {
        placement
            .validate(&self.machine)
            .expect("invalid placement for this machine");
        workload.validate().expect("invalid workload");

        let m = &self.machine;
        let s = m.sockets;
        let tps = &placement.threads_per_socket;
        // Derive a run-specific stream: same (seed, workload, placement)
        // → identical counters, different workloads/placements → fresh
        // noise draws.
        let mut rng = Rng::new(
            self.config
                .seed
                .wrapping_add(hash_str(&workload.name))
                .wrapping_add(hash_placement(tps)),
        );

        // ---- per-thread demand construction (constant across epochs) ----
        let ownership = workload.heterogeneity.ownership(tps);
        let demand_mult = workload.heterogeneity.demand_multipliers(tps);
        struct ThreadDemand {
            socket: usize,
            read_split: Vec<f64>,
            write_split: Vec<f64>,
            read_bps: f64,
            write_bps: f64,
            /// Bytes-per-instruction multiplier: hot-partition threads
            /// (SkewedOwnership) move more bytes per retired instruction,
            /// so their instruction counters do NOT scale with traffic —
            /// the §7 assumption violation.
            bytes_per_instr_mult: f64,
        }
        // Thread-stable irregularity stream: seeded by (run seed, workload)
        // but NOT the placement, so thread `tid` carries the same deviation
        // wherever it is pinned — moving threads moves the pattern, which
        // is exactly what defeats a placement-independent signature.
        let mut irr_rng = Rng::new(
            self.config.seed ^ hash_str(&workload.name) ^ 0x5EED_1DEA,
        );
        // Correlated placement-dependent drift (§6.2.1): real applications
        // change their access mix with both the thread *count* (partition
        // sizes, cache pressure) and the thread *imbalance* (halo ratios).
        // Every thread's split is blended `delta` of the way toward its
        // own bank (delta > 0) or a uniform spread (delta < 0); the shift
        // is identical for all threads, so it does not average out — it is
        // the systematic error floor of Fig 17.
        //
        // `occupancy - 0.75` anchors the count term at the profiling
        // placements (§5.1 uses 3/4 of the cores), so the two profiling
        // runs see a consistent, near-zero drift on every machine and the
        // fitted signatures stay machine-stable (Fig 14), while evaluation
        // sweeps at other occupancies pick up genuine model error.
        let n_total = placement.total() as f64;
        let imbalance = placement_imbalance(tps);
        // Blending toward a uniform spread barely moves mixtures that are
        // already interleave-heavy, so the drift always pulls toward the
        // thread's own bank ("more threads per socket → more of the
        // working set resolves locally"), with magnitude |·|.
        let occupancy = n_total / (m.total_cores() as f64);
        let delta = workload.placement_drift
            * (0.5 * imbalance + (occupancy - 0.75)).abs();
        let used: Vec<bool> = tps.iter().map(|&n| n > 0).collect();
        let n_used = used.iter().filter(|&&u| u).count().max(1) as f64;
        let drift = |split: Vec<f64>, own: usize| -> Vec<f64> {
            if delta == 0.0 {
                return split;
            }
            let a = delta.abs();
            split
                .iter()
                .enumerate()
                .map(|(d, &v)| {
                    let target = if delta > 0.0 {
                        if d == own { 1.0 } else { 0.0 }
                    } else if used[d] {
                        1.0 / n_used
                    } else {
                        0.0
                    };
                    (1.0 - a) * v + a * target
                })
                .collect()
        };
        let mut threads = Vec::with_capacity(placement.total());
        for (tid, socket) in placement.threads() {
            let mut trng = irr_rng.fork(tid as u64);
            let perturb = |split: Vec<f64>, rng: &mut Rng| -> Vec<f64> {
                if workload.irregularity == 0.0 {
                    return split;
                }
                let mut w: Vec<f64> = split
                    .iter()
                    .map(|&v| v * rng.jitter(workload.irregularity))
                    .collect();
                let sum: f64 = w.iter().sum();
                if sum > 0.0 {
                    for v in &mut w {
                        *v /= sum;
                    }
                }
                w
            };
            let read_split = perturb(
                drift(
                    workload.read_mixture.bank_split(socket, tps,
                                                     Some(&ownership)),
                    socket,
                ),
                &mut trng,
            );
            let write_split = perturb(
                drift(
                    workload
                        .write_mixture
                        .bank_split(socket, tps, Some(&ownership)),
                    socket,
                ),
                &mut trng,
            );
            // Expected access mix for the latency model.
            let rf = workload.read_fraction;
            let combined: Vec<f64> = read_split
                .iter()
                .zip(&write_split)
                .map(|(r, w)| rf * r + (1.0 - rf) * w)
                .collect();
            let peak = (workload.bw_per_thread * demand_mult[tid])
                .min(m.core_peak_bw);
            let demand = thread_demand(m, socket, &combined, peak,
                                       workload.latency_sensitivity);
            threads.push(ThreadDemand {
                socket,
                read_split,
                write_split,
                read_bps: demand * rf,
                write_bps: demand * (1.0 - rf),
                bytes_per_instr_mult: demand_mult[tid],
            });
        }

        // ---- flows (one per thread × bank × channel with demand > 0) ----
        struct FlowMeta {
            thread: usize,
            src: usize,
            dst: usize,
            ch: Channel,
        }
        let mut flows = Vec::new();
        let mut meta = Vec::new();
        for (t, td) in threads.iter().enumerate() {
            for d in 0..s {
                let rd = td.read_bps * td.read_split[d];
                if rd > 0.0 {
                    let mut rs = vec![m.read_chan(d)];
                    if td.socket != d {
                        rs.push(m.qpi_read_link(d, td.socket));
                    }
                    flows.push(Flow::new(rd, &rs));
                    meta.push(FlowMeta {
                        thread: t,
                        src: td.socket,
                        dst: d,
                        ch: Channel::Read,
                    });
                }
                let wr = td.write_bps * td.write_split[d];
                if wr > 0.0 {
                    let mut rs = vec![m.write_chan(d)];
                    if td.socket != d {
                        rs.push(m.qpi_write_link(td.socket, d));
                    }
                    flows.push(Flow::new(wr, &rs));
                    meta.push(FlowMeta {
                        thread: t,
                        src: td.socket,
                        dst: d,
                        ch: Channel::Write,
                    });
                }
            }
        }
        let demanded_bw: f64 = flows.iter().map(|f| f.demand).sum();
        let base_caps = m.capacities();
        let qpi_range = 2 * s..base_caps.len();

        // ---- epoch loop ---------------------------------------------------
        let mut counters = CounterSnapshot::new(s);
        let mut achieved_sum = 0.0;
        let mut per_socket = vec![0.0; s];
        let dt = self.config.epoch_s;
        // Reusable buffers for the coupled contention solve (hot path).
        let resources_refs: Vec<&[usize]> =
            flows.iter().map(|f| f.resources.as_slice()).collect();
        let mut demands_buf = vec![0.0f64; flows.len()];
        let mut alloc = vec![0.0f64; flows.len()];
        let mut scale = vec![1.0f64; threads.len()];
        let mut sat_buf = vec![1.0f64; threads.len()];
        let mut scratch = MaxminScratch::default();
        let mut thread_bytes = vec![0.0f64; threads.len()];
        for _epoch in 0..self.config.epochs {
            // QPI background traffic shaves link capacity this epoch.
            let mut caps = base_caps.clone();
            for r in qpi_range.clone() {
                caps[r] = self.config.noise.degrade_qpi(&mut rng, caps[r]);
            }
            // Thread-coupled contention: a program's access stream is
            // interleaved, so a thread stalls *as a whole* when any of its
            // flows hits a saturated resource — it cannot keep streaming
            // its local accesses while its remote loads crawl.  Iterate:
            // max-min over flows, then clamp each thread to its most
            // constrained flow's satisfaction; the freed capacity is
            // redistributed on the next round.  (Zero-allocation form:
            // demands scaled in place, buffers reused across epochs.)
            for sc in scale.iter_mut() {
                *sc = 1.0;
            }
            for _ in 0..3 {
                for ((d, f), fm) in
                    demands_buf.iter_mut().zip(&flows).zip(&meta)
                {
                    *d = f.demand * scale[fm.thread];
                }
                maxmin_into(&demands_buf, &resources_refs, &caps,
                            &mut alloc, &mut scratch);
                for s in sat_buf.iter_mut() {
                    *s = 1.0;
                }
                for ((a, d), fm) in
                    alloc.iter().zip(&demands_buf).zip(&meta)
                {
                    if *d > 0.0 {
                        let frac = a / d;
                        if frac < sat_buf[fm.thread] {
                            sat_buf[fm.thread] = frac;
                        }
                    }
                }
                let mut changed = false;
                for (sc, sa) in scale.iter_mut().zip(&sat_buf) {
                    if *sa < 1.0 - 1e-9 {
                        *sc *= sa;
                        changed = true;
                    }
                }
                if !changed {
                    break;
                }
            }
            // Final achieved traffic: every flow of a thread throttled by
            // the thread's common scale (fractions preserved).
            for (a, (f, fm)) in
                alloc.iter_mut().zip(flows.iter().zip(&meta))
            {
                *a = f.demand * scale[fm.thread];
            }

            for tb in thread_bytes.iter_mut() {
                *tb = 0.0;
            }
            for (a, fm) in alloc.iter().zip(&meta) {
                let bytes =
                    self.config.noise.jitter_counter(&mut rng, a * dt);
                counters.record_traffic(fm.src, fm.dst, fm.ch, bytes);
                thread_bytes[fm.thread] += a * dt;
                achieved_sum += a * dt;
                per_socket[fm.src] += a * dt;
            }
            // Instructions retire with achieved traffic; per-socket rate
            // wobble models frequency scaling (§2.1.1's IPC caveat).
            let mults: Vec<f64> = (0..s)
                .map(|_| self.config.noise.rate_multiplier(&mut rng))
                .collect();
            for (t, td) in threads.iter().enumerate() {
                counters.sockets[td.socket].instructions += thread_bytes[t]
                    * workload.instr_per_byte
                    * mults[td.socket]
                    / td.bytes_per_instr_mult;
            }
            // Absolute background traffic (kernel, daemons, prefetch junk)
            // lands on every counter component regardless of the workload.
            if self.config.noise.background_bw > 0.0 {
                for b in 0..s {
                    for ch in Channel::BOTH {
                        counters.banks[b].add_local(
                            ch,
                            self.config.noise.background_bytes(&mut rng, dt),
                        );
                        counters.banks[b].add_remote(
                            ch,
                            self.config.noise.background_bytes(&mut rng, dt),
                        );
                    }
                }
            }
            counters.elapsed_s += dt;
        }

        let total_s = self.config.epochs as f64 * dt;
        RunResult {
            run: ProfiledRun {
                counters,
                threads_per_socket: tps.clone(),
            },
            achieved_bw: achieved_sum / total_s,
            demanded_bw,
            per_socket_bw: per_socket.into_iter().map(|b| b / total_s)
                .collect(),
        }
    }
}

/// §6.2.1 signed placement-imbalance measure, socket-count-generic: the
/// mean signed pairwise thread-count difference over ordered socket
/// pairs, normalized by total threads —
///
/// ```text
///   imbalance = Σ_{i<j} (tps[i] - tps[j]) / (n_total * (S - 1))
/// ```
///
/// For S = 2 this is exactly the historical `(tps[0] - tps[1]) / n`
/// (one pair, denominator `n * 1`), so 2-socket simulations are
/// bit-identical to the pre-generalisation drift.  For S > 2 it is
/// nonzero for asymmetric placements — the regression the old
/// `if s == 2 { ... } else { 0.0 }` form silently zeroed, flattening
/// quad4's Fig-17-style error floor.
pub fn placement_imbalance(tps: &[usize]) -> f64 {
    let s = tps.len();
    let n_total: usize = tps.iter().sum();
    if s < 2 || n_total == 0 {
        return 0.0;
    }
    let mut sum = 0.0f64;
    for i in 0..s {
        for j in (i + 1)..s {
            sum += tps[i] as f64 - tps[j] as f64;
        }
    }
    sum / (n_total as f64 * (s - 1) as f64)
}

fn hash_str(s: &str) -> u64 {
    // FNV-1a.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn hash_placement(tps: &[usize]) -> u64 {
    let mut h = 0u64;
    for &t in tps {
        h = h.wrapping_mul(31).wrapping_add(t as u64 + 1);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::GB;
    use crate::workloads::synthetic::{index_chase, Pattern};
    use crate::workloads::{Heterogeneity, Mixture, Suite};

    fn sim(noiseless: bool) -> Simulator {
        let cfg = if noiseless {
            SimConfig::noiseless()
        } else {
            SimConfig::default()
        };
        Simulator::new(MachineTopology::xeon_e5_2630_v3(), cfg)
    }

    fn streaming(mix: Mixture, read_fraction: f64, bw: f64) -> WorkloadSpec {
        WorkloadSpec {
            name: "test-stream".into(),
            description: String::new(),
            suite: Suite::Synthetic,
            read_mixture: mix,
            write_mixture: mix,
            read_fraction,
            bw_per_thread: bw,
            instr_per_byte: 1.0,
            latency_sensitivity: 0.0,
            heterogeneity: Heterogeneity::Uniform,
            irregularity: 0.0,
            placement_drift: 0.0,
        }
    }

    #[test]
    fn local_uncontended_counters_match_demand() {
        let s = sim(true);
        let w = streaming(Mixture::pure_local(), 1.0, 1.0 * GB);
        let p = ThreadPlacement::new(vec![2, 2]);
        let r = s.run(&w, &p);
        // 4 threads × 1 GB/s local reads, far below the 44 GB/s channels.
        assert!((r.achieved_bw - 4.0 * GB).abs() < 1.0);
        assert_eq!(r.satisfaction(), 1.0);
        let c = &r.run.counters;
        // All traffic local, split 2/2.
        assert!((c.banks[0].local_read - 2.0 * GB * c.elapsed_s).abs() < 1.0);
        assert_eq!(c.banks[0].remote_read, 0.0);
        assert_eq!(c.banks[1].remote_read, 0.0);
        assert_eq!(c.channel_total(Channel::Write), 0.0);
    }

    #[test]
    fn static_remote_traffic_lands_on_remote_counter() {
        let s = sim(true);
        let w = streaming(Mixture::pure_static(1), 1.0, 1.0 * GB);
        let p = ThreadPlacement::new(vec![2, 1]);
        let r = s.run(&w, &p);
        let c = &r.run.counters;
        // Socket-0 threads hit bank 1 remotely; socket-1 thread locally.
        let t = c.elapsed_s;
        assert!((c.banks[1].remote_read - 2.0 * GB * t).abs() < 1.0);
        assert!((c.banks[1].local_read - 1.0 * GB * t).abs() < 1.0);
        assert_eq!(c.banks[0].total(), 0.0);
    }

    #[test]
    fn channel_saturation_caps_local_bandwidth() {
        let s = sim(true);
        // 8 threads × 10 GB/s demand onto one 44 GB/s read channel.
        let w = streaming(Mixture::pure_static(0), 1.0, 10.0 * GB);
        let p = ThreadPlacement::new(vec![8, 0]);
        let r = s.run(&w, &p);
        // Demand is clamped by core_peak (5.5 GB/s) → 44 GB/s total → at
        // exactly channel capacity.
        assert!(r.achieved_bw <= 44.0 * GB * 1.0001);
        assert!(r.achieved_bw >= 43.9 * GB, "{}", r.achieved_bw / GB);
    }

    #[test]
    fn qpi_starves_remote_readers() {
        let s = sim(true);
        let w = streaming(Mixture::pure_static(1), 1.0, 10.0 * GB);
        // All threads on socket 0 reading bank 1 through a 7.04 GB/s link.
        let p = ThreadPlacement::new(vec![8, 0]);
        let r = s.run(&w, &p);
        // Read data flows from bank 1 to socket 0: the (1, 0) read link.
        let qpi = MachineTopology::xeon_e5_2630_v3().link_read_cap(1, 0);
        assert!((r.achieved_bw - qpi).abs() < 0.01 * GB,
                "{} vs {}", r.achieved_bw / GB, qpi / GB);
        assert!(r.satisfaction() < 0.2);
    }

    #[test]
    fn writes_use_write_resources() {
        let s = sim(true);
        let w = streaming(Mixture::pure_static(1), 0.0, 10.0 * GB);
        let p = ThreadPlacement::new(vec![8, 0]);
        let r = s.run(&w, &p);
        // Write data flows from socket 0 to bank 1: the (0, 1) write link.
        let qpi_w =
            MachineTopology::xeon_e5_2630_v3().link_write_cap(0, 1);
        assert!((r.achieved_bw - qpi_w).abs() < 0.01 * GB);
        let c = &r.run.counters;
        assert_eq!(c.channel_total(Channel::Read), 0.0);
        assert!(c.banks[1].remote_write > 0.0);
    }

    #[test]
    fn instructions_track_achieved_bytes() {
        let s = sim(true);
        let mut w = streaming(Mixture::pure_local(), 1.0, 1.0 * GB);
        w.instr_per_byte = 2.0;
        let p = ThreadPlacement::new(vec![2, 2]);
        let r = s.run(&w, &p);
        let c = &r.run.counters;
        let bytes0 = c.banks[0].local_read;
        assert!((c.sockets[0].instructions - 2.0 * bytes0).abs()
                / c.sockets[0].instructions < 1e-9);
    }

    #[test]
    fn rate_skew_emerges_under_asymmetric_contention() {
        // Index chase with static placement: socket-1 threads run at full
        // local speed, socket-0 threads crawl through the QPI → the
        // per-thread instruction rates differ (the §5.2 phenomenon).
        let s = sim(true);
        let w = index_chase(Pattern::Static, 1);
        let p = ThreadPlacement::new(vec![4, 4]);
        let r = s.run(&w, &p);
        let rate0 = r.run.thread_rate(0);
        let rate1 = r.run.thread_rate(1);
        assert!(rate1 > rate0 * 1.5,
                "socket 1 should be much faster: {rate0} vs {rate1}");
    }

    #[test]
    fn deterministic_given_seed() {
        let s = sim(false);
        let w = index_chase(Pattern::Interleaved, 0);
        let p = ThreadPlacement::new(vec![3, 1]);
        let a = s.run(&w, &p);
        let b = s.run(&w, &p);
        assert_eq!(a.run, b.run);
    }

    #[test]
    fn noise_perturbs_counters_slightly() {
        let noisy = sim(false);
        let clean = sim(true);
        let w = index_chase(Pattern::Local, 0);
        let p = ThreadPlacement::new(vec![4, 4]);
        let a = noisy.run(&w, &p);
        let b = clean.run(&w, &p);
        let ra = a.run.counters.banks[0].local_read;
        let rb = b.run.counters.banks[0].local_read;
        assert_ne!(ra, rb);
        assert!((ra / rb - 1.0).abs() < 0.05, "noise should be percent-level");
    }

    #[test]
    fn skewed_ownership_shifts_traffic_towards_early_sockets() {
        let s = sim(true);
        let mut w = streaming(Mixture::pure_perthread(), 1.0, 0.5 * GB);
        let p = ThreadPlacement::new(vec![2, 2]);
        let uniform = s.run(&w, &p);
        w.heterogeneity = Heterogeneity::SkewedOwnership { decay: 0.5 };
        let skewed = s.run(&w, &p);
        let b0 = |r: &RunResult| r.run.counters.banks[0].total();
        assert!(b0(&skewed) > b0(&uniform) * 1.3,
                "hot head should concentrate on bank 0");
    }

    #[test]
    fn imbalance_is_byte_identical_to_the_two_socket_formula() {
        // The S-generic measure must not move any 2-socket bit: the
        // drift term feeds seeded, bit-reproducible counter streams.
        for t0 in 0..=8usize {
            for t1 in 0..=8usize {
                if t0 + t1 == 0 {
                    continue;
                }
                let old = (t0 as f64 - t1 as f64) / (t0 + t1) as f64;
                let new = placement_imbalance(&[t0, t1]);
                assert_eq!(old.to_bits(), new.to_bits(), "({t0},{t1})");
            }
        }
        assert_eq!(placement_imbalance(&[0, 0]), 0.0);
    }

    #[test]
    fn imbalance_is_nonzero_for_asymmetric_quad_placements() {
        // Regression for the `if s == 2 { ... } else { 0.0 }` bug: on
        // S > 2 machines asymmetric placements must register drift.
        assert_eq!(placement_imbalance(&[4, 4, 4, 4]), 0.0);
        let skew = placement_imbalance(&[8, 4, 2, 2]);
        assert!(skew > 0.0, "{skew}");
        // Mirrored skew flips sign (signed measure, like 2-socket).
        let anti = placement_imbalance(&[2, 2, 4, 8]);
        assert!((skew + anti).abs() < 1e-15, "{skew} vs {anti}");
        // Normalization keeps it in [-1, 1].
        assert!(placement_imbalance(&[8, 0, 0, 0]) <= 1.0);
    }

    #[test]
    fn quad_socket_drift_shifts_counters_under_asymmetric_placements() {
        // End-to-end regression: on quad4, a drift-prone workload under
        // an asymmetric placement at EXACTLY the anchor occupancy (3/4
        // of the cores — the count term is zero) must still drift,
        // i.e. its counters must differ from the drift-free run.  With
        // the old S==2-only imbalance the two runs were bit-identical
        // and quad simulations lost all placement-dependent drift.
        let quad = MachineTopology::synthetic_quad();
        let sim = Simulator::new(quad, SimConfig::noiseless());
        let p = ThreadPlacement::new(vec![8, 8, 6, 2]); // 24/32 = 0.75
        let mut w = streaming(Mixture::pure_interleave(), 1.0, 1.0 * GB);
        let base = sim.run(&w, &p);
        w.placement_drift = 0.5;
        let drifted = sim.run(&w, &p);
        assert_ne!(base.run.counters, drifted.run.counters,
                   "imbalance drift must engage on S > 2");
        // Drift pulls toward each thread's own bank: local read traffic
        // strictly grows on the most-loaded socket's bank.
        let local = |r: &RunResult| r.run.counters.banks[0].local_read;
        assert!(local(&drifted) > local(&base),
                "{} vs {}", local(&drifted), local(&base));
        // The symmetric placement stays drift-free at the anchor
        // occupancy (imbalance 0, occupancy exactly 0.75).
        let sym = ThreadPlacement::new(vec![6, 6, 6, 6]);
        let a = sim.run(&w, &sym);
        w.placement_drift = 0.0;
        let b = sim.run(&w, &sym);
        assert_eq!(a.run.counters, b.run.counters);
    }

    #[test]
    #[should_panic]
    fn autonuma_is_rejected() {
        let cfg = SimConfig {
            autonuma: true,
            ..SimConfig::default()
        };
        Simulator::new(MachineTopology::xeon_e5_2630_v3(), cfg);
    }

    #[test]
    #[should_panic]
    fn oversubscribed_placement_panics() {
        let s = sim(true);
        let w = streaming(Mixture::pure_local(), 1.0, GB);
        s.run(&w, &ThreadPlacement::new(vec![64, 0]));
    }
}
