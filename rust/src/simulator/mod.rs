//! Epoch-based NUMA machine simulator — the testbed substrate (DESIGN.md
//! §1): produces the performance-counter readings the paper samples from
//! real Xeons.
//!
//! * [`contention`] — max-min-fair water-filling over channels + QPI.
//! * [`placement`]  — thread pinning, §5.1 profiling placements, numactl
//!   page policies.
//! * [`latency`]    — latency-sensitive issue-rate (demand) model.
//! * [`noise`]      — counter jitter, QPI background traffic, rate wobble.
//! * [`engine`]     — the run loop tying it together.

pub mod contention;
pub mod engine;
pub mod latency;
pub mod noise;
pub mod placement;

pub use engine::{placement_imbalance, RunResult, SimConfig, Simulator};
pub use noise::NoiseConfig;
pub use placement::{MemoryPolicy, PageAllocator, ThreadPlacement};
