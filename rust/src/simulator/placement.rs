//! Thread placements and memory-placement policies.
//!
//! * [`ThreadPlacement`] — how many threads are pinned to each socket
//!   (always one thread per core, as in every experiment in the paper).
//!   Includes the §5.1 profiling placements: the *symmetric* run (equal
//!   threads per socket) and the *asymmetric* run (same total, skewed).
//! * [`MemoryPolicy`] + [`PageAllocator`] — numactl-style page placement
//!   (membind / interleave / first-touch / per-thread), simulated at page
//!   granularity.  The synthetic §6.1 benchmarks derive their ground-truth
//!   mixtures from these policies.

use crate::topology::MachineTopology;
use crate::workloads::Mixture;

/// Threads pinned per socket, one per core.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ThreadPlacement {
    pub threads_per_socket: Vec<usize>,
}

impl ThreadPlacement {
    pub fn new(threads_per_socket: Vec<usize>) -> ThreadPlacement {
        ThreadPlacement { threads_per_socket }
    }

    pub fn total(&self) -> usize {
        self.threads_per_socket.iter().sum()
    }

    pub fn sockets(&self) -> usize {
        self.threads_per_socket.len()
    }

    pub fn sockets_used(&self) -> usize {
        self.threads_per_socket.iter().filter(|&&n| n > 0).count()
    }

    /// Check against a machine: per-socket counts must fit the cores.
    pub fn validate(&self, machine: &MachineTopology) -> Result<(), String> {
        if self.sockets() != machine.sockets {
            return Err(format!(
                "placement covers {} sockets, machine has {}",
                self.sockets(),
                machine.sockets
            ));
        }
        for (s, &n) in self.threads_per_socket.iter().enumerate() {
            if n > machine.cores_per_socket {
                return Err(format!(
                    "socket {s}: {n} threads > {} cores (1 thread/core)",
                    machine.cores_per_socket
                ));
            }
        }
        if self.total() == 0 {
            return Err("placement has no threads".into());
        }
        Ok(())
    }

    /// Iterate threads in global load order (socket-major) as
    /// `(global_index, socket)`.
    pub fn threads(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.threads_per_socket
            .iter()
            .enumerate()
            .flat_map(|(s, &n)| std::iter::repeat(s).take(n))
            .enumerate()
    }

    // ---- §5.1 profiling placements -----------------------------------------

    /// The symmetric profiling run: `total` threads split evenly.  `total`
    /// must be even and leave room for the asymmetric run on the same
    /// thread count.
    pub fn symmetric(machine: &MachineTopology, total: usize)
        -> Result<ThreadPlacement, String> {
        if total % machine.sockets != 0 {
            return Err(format!(
                "symmetric run needs a multiple of {} threads",
                machine.sockets
            ));
        }
        let p = ThreadPlacement::new(vec![
            total / machine.sockets;
            machine.sockets
        ]);
        p.validate(machine)?;
        Ok(p)
    }

    /// The asymmetric profiling run: same total, skewed ~2:1 across the
    /// sockets (paper Fig 7's example is (4, 2) on 6-core sockets).  A
    /// *moderate*, machine-independent imbalance keeps the asymmetric-run
    /// contamination of the fit comparable across machines — maxing the
    /// skew out to the core budget would make fitted signatures
    /// machine-dependent (Fig 14 would degrade).
    ///
    /// For S = 2 this is the paper's exact 2:1 split (kept byte-for-byte
    /// so every seeded paper-machine run reproduces).  For S > 2 the
    /// symmetric placement is tilted by moving threads from the last
    /// socket to the first, which gives the §5.5 regression distinct
    /// thread shares without starving any socket.
    pub fn asymmetric(machine: &MachineTopology, total: usize)
        -> Result<ThreadPlacement, String> {
        if machine.sockets == 2 {
            let hi = ((total * 2) / 3).min(machine.cores_per_socket);
            let lo = total - hi;
            if lo == 0 || hi == lo || lo > machine.cores_per_socket {
                return Err(format!(
                    "cannot build an asymmetric placement of {total} threads"
                ));
            }
            let p = ThreadPlacement::new(vec![hi, lo]);
            p.validate(machine)?;
            return Ok(p);
        }
        if total % machine.sockets != 0 {
            return Err(format!(
                "asymmetric run needs a multiple of {} threads",
                machine.sockets
            ));
        }
        let per = total / machine.sockets;
        let shift = (per / 2).min(machine.cores_per_socket - per);
        if shift == 0 || shift >= per {
            return Err(format!(
                "cannot build an asymmetric placement of {total} threads \
                 on {} sockets of {} cores",
                machine.sockets, machine.cores_per_socket
            ));
        }
        let mut tps = vec![per; machine.sockets];
        tps[0] += shift;
        tps[machine.sockets - 1] -= shift;
        let p = ThreadPlacement::new(tps);
        p.validate(machine)?;
        Ok(p)
    }

    /// The profiling thread count the coordinator uses on a machine: the
    /// paper leaves cores spare so symmetric and asymmetric runs can use
    /// the *same* count (§5.1).  We use 3/4 of one socket's cores per
    /// socket, rounded to even ≥ 2 per socket.
    pub fn profiling_total(machine: &MachineTopology) -> usize {
        let per_socket = (machine.cores_per_socket * 3 / 4).max(2);
        per_socket * machine.sockets
    }

    /// All thread distributions of `total` threads across 2 sockets
    /// respecting 1 thread/core — the §6.2.2 evaluation sweep.
    pub fn all_splits(machine: &MachineTopology, total: usize)
        -> Vec<ThreadPlacement> {
        assert_eq!(machine.sockets, 2);
        let mut out = Vec::new();
        for t0 in 0..=total {
            let t1 = total - t0;
            if t0 <= machine.cores_per_socket
                && t1 <= machine.cores_per_socket
            {
                out.push(ThreadPlacement::new(vec![t0, t1]));
            }
        }
        out
    }
}

/// numactl-style memory policies (paper §3 / §6.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemoryPolicy {
    /// All pages bound to one socket (`numactl --membind=<s>`).
    Membind(usize),
    /// Pages interleaved round-robin across all sockets
    /// (`numactl --interleave=all`).
    Interleave,
    /// First-touch: each page lands on the socket of the thread that
    /// touches it first (Linux default; the paper's Local placement).
    FirstTouch,
    /// Each thread allocates 1/n of the pages locally, all threads then
    /// share them (the paper's Per-thread pattern).
    PerThreadShared,
}

/// Page-granularity allocation bookkeeping: which bank holds each page.
/// Used by the synthetic benchmarks to derive mixtures and by tests to
/// validate policy semantics.
#[derive(Clone, Debug)]
pub struct PageAllocator {
    pub sockets: usize,
    /// `pages[i]` = socket owning page i.
    pub pages: Vec<usize>,
}

impl PageAllocator {
    /// Allocate `n_pages` under `policy` for the given placement.  For
    /// FirstTouch/PerThreadShared, pages are touched by threads in
    /// round-robin (FirstTouch) or contiguous-chunk (PerThreadShared)
    /// order, mirroring the usual OpenMP loop split.
    pub fn allocate(policy: MemoryPolicy, n_pages: usize,
                    placement: &ThreadPlacement) -> PageAllocator {
        let sockets = placement.sockets();
        let thread_sockets: Vec<usize> =
            placement.threads().map(|(_, s)| s).collect();
        let n_threads = thread_sockets.len().max(1);
        let pages = (0..n_pages)
            .map(|i| match policy {
                MemoryPolicy::Membind(s) => s,
                MemoryPolicy::Interleave => i % sockets,
                MemoryPolicy::FirstTouch => {
                    // Static round-robin loop split: page i touched by
                    // thread i % n.
                    thread_sockets[i % n_threads]
                }
                MemoryPolicy::PerThreadShared => {
                    // Contiguous chunks: thread j owns pages
                    // [j*n_pages/n, (j+1)*n_pages/n).
                    let j = (i * n_threads) / n_pages.max(1);
                    thread_sockets[j.min(n_threads - 1)]
                }
            })
            .collect();
        PageAllocator { sockets, pages }
    }

    /// Fraction of pages on each socket.
    pub fn socket_shares(&self) -> Vec<f64> {
        let mut counts = vec![0usize; self.sockets];
        for &p in &self.pages {
            counts[p] += 1;
        }
        let total = self.pages.len().max(1) as f64;
        counts.into_iter().map(|c| c as f64 / total).collect()
    }
}

/// Map a memory policy to the §3 mixture it induces for uniform access —
/// what numactl did for the paper's synthetic benchmarks.
pub fn policy_mixture(policy: MemoryPolicy) -> Mixture {
    match policy {
        MemoryPolicy::Membind(s) => Mixture::pure_static(s),
        MemoryPolicy::Interleave => {
            // numactl --interleave=all spreads over all banks regardless
            // of thread placement (physical interleave).
            Mixture::pure_interleave().with_physical_interleave()
        }
        MemoryPolicy::FirstTouch => Mixture::pure_local(),
        MemoryPolicy::PerThreadShared => Mixture::pure_perthread(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m8() -> MachineTopology {
        MachineTopology::xeon_e5_2630_v3()
    }

    fn m18() -> MachineTopology {
        MachineTopology::xeon_e5_2699_v3()
    }

    #[test]
    fn symmetric_and_asymmetric_profiles() {
        let sym = ThreadPlacement::symmetric(&m8(), 12).unwrap();
        assert_eq!(sym.threads_per_socket, vec![6, 6]);
        let asym = ThreadPlacement::asymmetric(&m8(), 12).unwrap();
        assert_eq!(asym.total(), 12);
        assert_ne!(asym.threads_per_socket[0], asym.threads_per_socket[1]);
        asym.validate(&m8()).unwrap();
    }

    #[test]
    fn profiling_total_leaves_headroom() {
        // §5.1: spare cores let the asymmetric run keep 1 thread/core.
        for m in [m8(), m18()] {
            let total = ThreadPlacement::profiling_total(&m);
            assert!(ThreadPlacement::symmetric(&m, total).is_ok());
            assert!(ThreadPlacement::asymmetric(&m, total).is_ok(),
                    "machine {} total {total}", m.name);
        }
    }

    #[test]
    fn multi_socket_profiling_placements() {
        let quad = MachineTopology::synthetic_quad();
        let total = ThreadPlacement::profiling_total(&quad);
        let sym = ThreadPlacement::symmetric(&quad, total).unwrap();
        assert!(sym.threads_per_socket.iter().all(|&t| t == total / 4));
        let asym = ThreadPlacement::asymmetric(&quad, total).unwrap();
        assert_eq!(asym.total(), total);
        assert_ne!(asym.threads_per_socket[0],
                   asym.threads_per_socket[3]);
        asym.validate(&quad).unwrap();
        // The 2-socket formula is untouched (seeded runs must reproduce).
        let asym2 = ThreadPlacement::asymmetric(&m8(), 12).unwrap();
        assert_eq!(asym2.threads_per_socket, vec![8, 4]);
    }

    #[test]
    fn validate_rejects_oversubscription() {
        let p = ThreadPlacement::new(vec![9, 0]);
        assert!(p.validate(&m8()).is_err());
        let p2 = ThreadPlacement::new(vec![0, 0]);
        assert!(p2.validate(&m8()).is_err());
    }

    #[test]
    fn threads_iterate_socket_major() {
        let p = ThreadPlacement::new(vec![2, 1]);
        let v: Vec<(usize, usize)> = p.threads().collect();
        assert_eq!(v, vec![(0, 0), (1, 0), (2, 1)]);
    }

    #[test]
    fn all_splits_respect_core_budget() {
        let splits = ThreadPlacement::all_splits(&m8(), 8);
        // t0 from 0..=8 → 9 splits, all within 8 cores/socket.
        assert_eq!(splits.len(), 9);
        let splits12 = ThreadPlacement::all_splits(&m8(), 12);
        // t0 in 4..=8 → 5 splits.
        assert_eq!(splits12.len(), 5);
        for s in splits12 {
            s.validate(&m8()).unwrap();
        }
    }

    #[test]
    fn membind_puts_everything_on_one_socket() {
        let p = ThreadPlacement::new(vec![2, 2]);
        let a = PageAllocator::allocate(MemoryPolicy::Membind(1), 1000, &p);
        assert_eq!(a.socket_shares(), vec![0.0, 1.0]);
    }

    #[test]
    fn interleave_splits_evenly() {
        let p = ThreadPlacement::new(vec![2, 2]);
        let a = PageAllocator::allocate(MemoryPolicy::Interleave, 1000, &p);
        let sh = a.socket_shares();
        assert!((sh[0] - 0.5).abs() < 1e-3, "{sh:?}");
    }

    #[test]
    fn first_touch_follows_thread_sockets() {
        // 3 threads on socket 0, 1 on socket 1 → 3/4 of pages on socket 0.
        let p = ThreadPlacement::new(vec![3, 1]);
        let a = PageAllocator::allocate(MemoryPolicy::FirstTouch, 4000, &p);
        let sh = a.socket_shares();
        assert!((sh[0] - 0.75).abs() < 1e-3, "{sh:?}");
    }

    #[test]
    fn perthread_chunks_follow_thread_share() {
        let p = ThreadPlacement::new(vec![1, 3]);
        let a =
            PageAllocator::allocate(MemoryPolicy::PerThreadShared, 4000, &p);
        let sh = a.socket_shares();
        assert!((sh[0] - 0.25).abs() < 1e-2, "{sh:?}");
    }

    #[test]
    fn policy_mixtures_are_pure() {
        assert_eq!(policy_mixture(MemoryPolicy::Membind(1)).static_frac, 1.0);
        assert_eq!(policy_mixture(MemoryPolicy::FirstTouch).local_frac, 1.0);
        assert_eq!(policy_mixture(MemoryPolicy::Interleave).interleave_frac,
                   1.0);
        assert_eq!(
            policy_mixture(MemoryPolicy::PerThreadShared).perthread_frac,
            1.0
        );
    }
}
