//! Thread issue-rate model: how memory latency shapes a thread's bandwidth
//! *demand* before any contention is applied.
//!
//! A fully prefetchable streaming kernel is insensitive to access latency —
//! its demand is the core's peak issue bandwidth.  A dependent-load chase
//! (the paper's synthetic, hash-join probes, sparse gathers) issues one
//! access per round-trip, so its demand scales with `1 / latency`.  Real
//! workloads sit between the two; `WorkloadSpec::latency_sensitivity`
//! interpolates:
//!
//! ```text
//! demand = peak * ((1 - s) + s * lat_local / lat_avg)
//! ```
//!
//! where `lat_avg` is the thread's expected access latency under its bank
//! split.  With `s = 1` and an all-remote split this reduces to the
//! classic latency-bound slowdown `lat_local / lat_remote`; with `s = 0`
//! placement does not affect demand at all (only contention does).

use crate::topology::MachineTopology;

/// Expected access latency (ns) for a thread on `socket` whose traffic
/// lands on banks per `bank_split`.
pub fn avg_latency_ns(machine: &MachineTopology, socket: usize,
                      bank_split: &[f64]) -> f64 {
    debug_assert_eq!(bank_split.len(), machine.sockets);
    let wsum: f64 = bank_split.iter().sum();
    if wsum <= 0.0 {
        return machine.latency_ns(socket, socket);
    }
    bank_split
        .iter()
        .enumerate()
        .map(|(d, w)| w * machine.latency_ns(socket, d))
        .sum::<f64>()
        / wsum
}

/// Uncontended bandwidth demand (bytes/s) of one thread.
pub fn thread_demand(machine: &MachineTopology, socket: usize,
                     bank_split: &[f64], peak_bw: f64,
                     latency_sensitivity: f64) -> f64 {
    let lat = avg_latency_ns(machine, socket, bank_split);
    let scale = (1.0 - latency_sensitivity)
        + latency_sensitivity * machine.latency_ns(socket, socket) / lat;
    peak_bw * scale
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> MachineTopology {
        // local 90 ns, remote 200 ns.
        MachineTopology::xeon_e5_2630_v3()
    }

    #[test]
    fn all_local_latency() {
        assert_eq!(avg_latency_ns(&m(), 0, &[1.0, 0.0]), 90.0);
        assert_eq!(avg_latency_ns(&m(), 1, &[0.0, 1.0]), 90.0);
    }

    #[test]
    fn all_remote_latency() {
        assert_eq!(avg_latency_ns(&m(), 0, &[0.0, 1.0]), 200.0);
    }

    #[test]
    fn mixed_latency_interpolates() {
        let lat = avg_latency_ns(&m(), 0, &[0.5, 0.5]);
        assert!((lat - 145.0).abs() < 1e-9);
    }

    #[test]
    fn streaming_demand_ignores_placement() {
        let local = thread_demand(&m(), 0, &[1.0, 0.0], 1e9, 0.0);
        let remote = thread_demand(&m(), 0, &[0.0, 1.0], 1e9, 0.0);
        assert_eq!(local, remote);
        assert_eq!(local, 1e9);
    }

    #[test]
    fn dependent_chase_demand_scales_with_latency() {
        let local = thread_demand(&m(), 0, &[1.0, 0.0], 1e9, 1.0);
        let remote = thread_demand(&m(), 0, &[0.0, 1.0], 1e9, 1.0);
        assert_eq!(local, 1e9);
        assert!((remote - 1e9 * 90.0 / 200.0).abs() < 1e-3);
    }

    #[test]
    fn sensitivity_interpolates_between_extremes() {
        let half = thread_demand(&m(), 0, &[0.0, 1.0], 1e9, 0.5);
        let lo = thread_demand(&m(), 0, &[0.0, 1.0], 1e9, 1.0);
        let hi = thread_demand(&m(), 0, &[0.0, 1.0], 1e9, 0.0);
        assert!(lo < half && half < hi);
        assert!((half - 0.5 * (lo + hi)).abs() < 1e-6);
    }

    #[test]
    fn empty_split_defaults_to_local() {
        assert_eq!(avg_latency_ns(&m(), 0, &[0.0, 0.0]), 90.0);
    }

    #[test]
    fn asymmetric_matrix_drives_per_socket_latency() {
        // A latency matrix no local/remote scalar pair can express: each
        // socket has its own local latency and sees different remote
        // costs depending on direction.
        let mut m = MachineTopology::uniform("asym2", 2, 8, 44e9, 30e9,
                                             7e9, 6.9e9, 90.0, 200.0,
                                             5.5e9, 0.0);
        m.latency_matrix_ns = vec![90.0, 200.0, 140.0, 95.0];
        m.validate().unwrap();
        assert_eq!(avg_latency_ns(&m, 1, &[0.0, 1.0]), 95.0);
        assert_eq!(avg_latency_ns(&m, 1, &[1.0, 0.0]), 140.0);
        assert_eq!(avg_latency_ns(&m, 1, &[0.0, 0.0]), 95.0);
        // Demand scales against the *thread's own* local latency, so a
        // socket-1 chase at home runs at full peak...
        assert_eq!(thread_demand(&m, 1, &[0.0, 1.0], 1e9, 1.0), 1e9);
        // ...and its remote slowdown uses the 140 ns it actually sees —
        // different from socket 0's mirrored placement (90/200).
        let s1_remote = thread_demand(&m, 1, &[1.0, 0.0], 1e9, 1.0);
        let s0_remote = thread_demand(&m, 0, &[0.0, 1.0], 1e9, 1.0);
        assert!((s1_remote - 1e9 * 95.0 / 140.0).abs() < 1e-3);
        assert!((s0_remote - 1e9 * 90.0 / 200.0).abs() < 1e-3);
        assert!(s1_remote > s0_remote);
    }
}
