//! Evaluation aggregations: the numbers behind each figure.
//!
//! * Fig 14/15 — signature stability between machines.
//! * Fig 17    — error CDF over all measurements (headline: median 2.34 %).
//! * Fig 18    — per-benchmark average error vs average bandwidth.

use std::collections::BTreeMap;

use crate::coordinator::Evaluation;
use crate::model::signature::BandwidthSignature;
use crate::util::stats::{Cdf, Summary};

/// Fig 14 row: per-benchmark signature change between two machines.
#[derive(Clone, Debug)]
pub struct StabilityRow {
    pub workload: String,
    /// % of read bandwidth reallocated between the two fitted signatures.
    pub read_change_pct: f64,
    pub write_change_pct: f64,
    /// Change of the combined-channel signature — the robust metric the
    /// paper uses to defuse the equake-writes outlier.
    pub combined_change_pct: f64,
}

/// Compare fitted signatures across two machines (Fig 14 / Fig 15).
pub fn stability(a: &Evaluation, b: &Evaluation, sockets: usize)
    -> Vec<StabilityRow> {
    let index: BTreeMap<&str, &BandwidthSignature> = b
        .signatures
        .iter()
        .map(|(n, s)| (n.as_str(), s))
        .collect();
    a.signatures
        .iter()
        .filter_map(|(name, sa)| {
            let sb = index.get(name.as_str())?;
            Some(StabilityRow {
                workload: name.clone(),
                read_change_pct: 100.0
                    * sa.read.reallocation(&sb.read, sockets),
                write_change_pct: 100.0
                    * sa.write.reallocation(&sb.write, sockets),
                combined_change_pct: 100.0
                    * sa.combined.reallocation(&sb.combined, sockets),
            })
        })
        .collect()
}

/// Fig 15: CDF over the per-benchmark combined-signature changes.
pub fn stability_cdf(rows: &[StabilityRow]) -> Cdf {
    Cdf::of(&rows.iter().map(|r| r.combined_change_pct).collect::<Vec<_>>())
}

/// Fig 17: the error CDF across all measurement points.
pub fn error_cdf(ev: &Evaluation) -> Cdf {
    Cdf::of(&ev.errors())
}

/// Fig 18 row: per-benchmark average error vs average bandwidth.
#[derive(Clone, Debug)]
pub struct AccuracyRow {
    pub workload: String,
    pub avg_err_pct: f64,
    pub avg_bandwidth: f64,
    pub n_points: usize,
}

pub fn accuracy_by_benchmark(ev: &Evaluation) -> Vec<AccuracyRow> {
    let mut grouped: BTreeMap<&str, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for r in &ev.records {
        let e = grouped.entry(&r.workload).or_default();
        e.0.push(r.err_pct);
        e.1.push(r.run_bandwidth);
    }
    grouped
        .into_iter()
        .map(|(name, (errs, bws))| AccuracyRow {
            workload: name.to_string(),
            avg_err_pct: Summary::of(&errs).mean,
            avg_bandwidth: Summary::of(&bws).mean,
            n_points: errs.len(),
        })
        .collect()
}

/// The paper's headline claim, checked in one place: over the pooled
/// measurements, report (median %, frac ≤ 2.5 %, frac ≤ 10 %).
pub fn headline(evs: &[&Evaluation]) -> (f64, f64, f64) {
    let mut all = Vec::new();
    for ev in evs {
        all.extend(ev.errors());
    }
    let cdf = Cdf::of(&all);
    (cdf.median(), cdf.at(2.5), cdf.at(10.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ErrorRecord;
    use crate::model::signature::ChannelSignature;

    fn mk_eval(machine: &str, sigs: Vec<(&str, f64)>, errs: Vec<f64>)
        -> Evaluation {
        Evaluation {
            machine: machine.to_string(),
            signatures: sigs
                .into_iter()
                .map(|(n, local)| {
                    let c = ChannelSignature::new(0.1, local, 0.2, 0);
                    (
                        n.to_string(),
                        BandwidthSignature {
                            read: c,
                            write: c,
                            combined: c,
                            read_bytes: 1.0,
                            write_bytes: 1.0,
                        },
                    )
                })
                .collect(),
            records: errs
                .into_iter()
                .map(|e| ErrorRecord {
                    workload: "w".into(),
                    split: [4, 4],
                    channel: "read",
                    bank: 0,
                    kind: "local",
                    measured: 1.0,
                    predicted: 1.0,
                    err_pct: e,
                    run_bandwidth: 1e9,
                })
                .collect(),
        }
    }

    #[test]
    fn stability_pairs_by_name() {
        let a = mk_eval("m1", vec![("x", 0.3), ("y", 0.5)], vec![]);
        let b = mk_eval("m2", vec![("y", 0.5), ("x", 0.4)], vec![]);
        let rows = stability(&a, &b, 2);
        assert_eq!(rows.len(), 2);
        let x = rows.iter().find(|r| r.workload == "x").unwrap();
        // local 0.3 → 0.4: 0.1 mass moved → 10%.
        assert!((x.combined_change_pct - 10.0).abs() < 1e-9);
        let y = rows.iter().find(|r| r.workload == "y").unwrap();
        assert!(y.combined_change_pct.abs() < 1e-9);
    }

    #[test]
    fn headline_median_and_fractions() {
        let ev = mk_eval("m", vec![], vec![1.0, 2.0, 3.0, 20.0]);
        let (median, at25, at10) = headline(&[&ev]);
        assert!((median - 2.5).abs() < 1e-9);
        assert_eq!(at25, 0.5);
        assert_eq!(at10, 0.75);
    }

    #[test]
    fn accuracy_rows_group_by_benchmark() {
        let mut ev = mk_eval("m", vec![], vec![1.0, 3.0]);
        ev.records[1].workload = "other".into();
        let rows = accuracy_by_benchmark(&ev);
        assert_eq!(rows.len(), 2);
    }
}
