//! `numabw` command-line interface.
//!
//! Subcommands:
//!   machines   — list the built-in machine topologies (paper §2, Fig 2)
//!   discover   — build a topology file from Linux sysfs (node distances,
//!                cpulists, per-node memory; bandwidth seeded from
//!                distance ratios, overridable)
//!   workloads  — list the workload suite (paper Table 1)
//!   profile    — run the two §5.1 profiling runs for one workload
//!   fit        — profile + fit, print the bandwidth signature (§5)
//!   predict    — apply a fitted signature to a placement (§4)
//!   advise     — rank every thread placement (batched+cached serving;
//!                store-backed fit-once serving via --store)
//!   serve      — long-lived JSONL daemon (stdin/stdout, TCP, or unix
//!                socket via --listen): concurrent coalescing front-end
//!                + store-backed model registry
//!   evaluate   — full measured-vs-predicted sweep (§6.2.2, Figs 16–18)
//!   quickstart — tiny end-to-end demo

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{
    advisor, evaluate_suite, profile, FitRequest, PredictionService,
    SignatureStore,
};
use crate::eval;
use crate::model::misfit;
use crate::model::signature::BandwidthSignature;
use crate::report;
use crate::server::{self, ModelRegistry, ServeOptions};
use crate::simulator::{SimConfig, Simulator, ThreadPlacement};
use crate::topology::MachineTopology;
use crate::util::args::Args;
use crate::workloads::{self, suite, WorkloadSpec};

pub fn main_with(args: Vec<String>) -> Result<()> {
    let args = Args::parse(args);
    // Per-subcommand flag allowlists: a typo (or a removed flag such as
    // the pre-backend-trait `--hlo`) must error, not silently change
    // which engine serves.
    let known = |allowed: &[&str]| -> Result<()> {
        args.ensure_known(allowed).map_err(|e| anyhow!("{e}"))
    };
    match args.command.as_deref() {
        Some("machines") => known(&[]).and_then(|_| cmd_machines()),
        Some("discover") => known(&[
            "sysfs", "out", "name", "local-read-gbs", "local-write-gbs",
            "latency-ns", "core-peak-gbs", "price-usd",
        ])
        .and_then(|_| cmd_discover(&args)),
        Some("workloads") => known(&[]).and_then(|_| cmd_workloads()),
        Some("profile") => known(&["workload", "machine", "seed"])
            .and_then(|_| cmd_profile(&args)),
        Some("fit") => known(&[
            "workload", "machine", "engine", "engine-threads", "save",
            "seed",
        ])
        .and_then(|_| cmd_fit(&args)),
        Some("predict") => known(&[
            "workload", "machine", "engine", "engine-threads", "store",
            "t0", "t1", "split", "seed",
        ])
        .and_then(|_| cmd_predict(&args)),
        Some("advise") => known(&[
            "workload", "machine", "threads", "top", "engine",
            "engine-threads", "store", "seed",
        ])
        .and_then(|_| cmd_advise(&args)),
        Some("serve") => known(&[
            "listen", "store", "seed", "batch", "window-ms", "engine",
            "engine-threads", "trace-out", "metrics-dump", "shards",
            "workers",
        ])
        .and_then(|_| cmd_serve(&args)),
        Some("evaluate") => {
            known(&["machine", "engine", "engine-threads", "seed"])
                .and_then(|_| cmd_evaluate(&args))
        }
        Some("quickstart") => known(&[]).and_then(|_| cmd_quickstart()),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "\
numabw — NUMA bandwidth-pattern modeling (paper reproduction)

USAGE: numabw <subcommand> [flags]

  machines                          list machine topologies
  discover  [--sysfs DIR] [--out F] [--name N] [--local-read-gbs X]
            [--local-write-gbs X] [--latency-ns X] [--core-peak-gbs X]
            [--price-usd X]
                                    build a topology file from Linux
                                    sysfs (default root /sys; any
                                    directory with the same layout
                                    works).  Node distances, cpulists and
                                    per-node memory come from sysfs;
                                    bandwidth/latency are seeded from the
                                    distance ratios and the overridable
                                    scales above.  Writes the versioned
                                    topology JSON to --out (stdout
                                    otherwise); use it anywhere as
                                    --machine @F
  workloads                         list the Table-1 workload suite
  profile   --workload W [--machine M]       run the two §5.1 runs
  fit       --workload W [--machine M] [--engine E] [--save F]
                                    fit + print (optionally store) the
                                    signature
  predict   --workload W (--t0 N --t1 N | --split a,b,..) [--machine M]
            [--engine E] [--store F]
                                    predict a placement's traffic matrix
                                    (from a stored signature if --store;
                                    --split takes one count per socket)
  advise    --workload W [--machine M] [--threads N] [--top K]
            [--engine E] [--store F] [--seed S]
                                    rank every valid thread placement by
                                    predicted bandwidth (Pandia-style;
                                    batched+cached serving path); with
                                    --store, fit once into F and serve
                                    forever (seed-guarded)
  serve     [--listen A] [--store F] [--seed S] [--batch N]
            [--window-ms W] [--engine E] [--trace-out F]
            [--metrics-dump F] [--shards N] [--workers M]
                                    line-delimited JSON daemon: ops
                                    counters|perf|advise|stats|metrics
                                    through the concurrent coalescing
                                    front-end + model registry.  Default
                                    transport is stdin/stdout; --listen
                                    serves TCP (host:port) or a unix
                                    socket (unix:/path) through a fixed
                                    pool of --workers connection threads
                                    (default 8; over-capacity connections
                                    get one error line and are closed).
                                    --shards N (default 1, max 16) runs N
                                    front-end dispatcher shards; queries
                                    route by a deterministic key hash, so
                                    results are bit-identical to one
                                    shard — raise it when one dispatcher
                                    saturates a core, keep the default
                                    for small fleets (one shard batches
                                    best).  Size --workers to expected
                                    concurrent connections, not shards.
                                    --trace-out records request spans and
                                    writes Chrome trace_event JSON at
                                    shutdown (load into chrome://tracing);
                                    --metrics-dump writes the full
                                    histogram/counter state as JSON
  evaluate  [--machine M] [--engine E] [--seed S]   full §6.2.2 sweep
  quickstart                        tiny end-to-end demo

Flags: --machine xeon8|xeon18|quad4|@topology.json (default xeon18;
quad4 is the synthetic 4-socket machine — every subcommand is
socket-count-generic; @file loads a topology file, e.g. one written by
`numabw discover`, so asymmetric machines serve end to end);
--engine reference|native|hlo (default reference: the per-row f64
model; native: the batched f32 engine, any socket count; hlo: the
HLO-text pipelines through the in-repo interpreter — AOT artifacts when
present, emitted per-S modules otherwise; `pjrt` is a legacy alias);
--engine-threads N (default 1; native only) splits engine batches of
>= 32 rows across N pooled worker threads — results are bit-identical
to N=1, so size it to spare cores (it multiplies with --shards: total
engine threads = shards x N);
--seed u64.";

fn machine_flag(args: &Args) -> Result<MachineTopology> {
    let spec = args.get_or("machine", "xeon18");
    crate::topology::file::resolve_machine(spec).map_err(|e| anyhow!(e))
}

fn workload_flag(args: &Args) -> Result<WorkloadSpec> {
    let name = args
        .get("workload")
        .ok_or_else(|| anyhow!("--workload required"))?;
    workloads::find(name)
        .ok_or_else(|| anyhow!("unknown workload {name:?} (see `numabw workloads`)"))
}

fn seed_flag(args: &Args) -> u64 {
    args.get("seed")
        .map(|s| s.parse().expect("--seed: u64"))
        .unwrap_or(SimConfig::default().seed)
}

fn service_flag(args: &Args) -> Result<PredictionService> {
    let threads = args.get_usize("engine-threads", 1);
    if threads == 0 {
        bail!("--engine-threads must be >= 1");
    }
    PredictionService::by_name_with_threads(
        args.get_or("engine", "reference"),
        threads,
    )
}

fn sim_flag(args: &Args, machine: MachineTopology) -> Simulator {
    Simulator::new(machine,
                   SimConfig::default().with_seed(seed_flag(args)))
}

fn cmd_machines() -> Result<()> {
    let rows: Vec<Vec<String>> = MachineTopology::builtin_machines()
        .iter()
        .map(|m| {
            vec![
                m.name.clone(),
                format!("{}x{}", m.sockets, m.cores_per_socket),
                report::fmt_bw(m.chan_read_cap(0)),
                report::fmt_bw(m.chan_write_cap(0)),
                format!("{:.2}x", m.link_read_cap(0, 1) / m.chan_read_cap(0)),
                format!("{:.2}x",
                        m.link_write_cap(0, 1) / m.chan_write_cap(0)),
                format!("${:.0}", m.price_usd),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(
            &["machine", "cores", "local rd", "local wr", "remote rd",
              "remote wr", "price/cpu"],
            &rows
        )
    );
    Ok(())
}

fn cmd_discover(args: &Args) -> Result<()> {
    use crate::topology::{discover, file, GB};
    let defaults = discover::DiscoverOptions::default();
    let opts = discover::DiscoverOptions {
        name: args.get("name").map(str::to_string),
        local_read_bw: args
            .get_f64("local-read-gbs", defaults.local_read_bw / GB) * GB,
        local_write_bw: args
            .get_f64("local-write-gbs", defaults.local_write_bw / GB) * GB,
        local_latency_ns: args
            .get_f64("latency-ns", defaults.local_latency_ns),
        core_peak_bw: args
            .get_f64("core-peak-gbs", defaults.core_peak_bw / GB) * GB,
        price_usd: args.get_f64("price-usd", defaults.price_usd),
    };
    let root = std::path::PathBuf::from(args.get_or("sysfs", "/sys"));
    let t = discover::discover_from(&root, &opts).map_err(|e| anyhow!(e))?;
    match args.get("out") {
        Some(path) => {
            let path = std::path::Path::new(path);
            file::save(&t, path).map_err(|e| anyhow!(e))?;
            println!(
                "discovered {} ({} sockets x {} cores) from {} -> {}",
                t.name, t.sockets, t.cores_per_socket, root.display(),
                path.display()
            );
            println!("use it anywhere: --machine @{}", path.display());
        }
        None => println!("{}", t.to_json().encode()),
    }
    Ok(())
}

fn cmd_workloads() -> Result<()> {
    let rows: Vec<Vec<String>> = suite::table1()
        .iter()
        .map(|w| {
            vec![
                w.name.clone(),
                w.suite.tag().to_string(),
                w.description.clone(),
                format!("{:.2}", w.read_fraction),
                report::fmt_bw(w.bw_per_thread),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(&["name", "suite", "description", "rd frac",
                        "bw/thread"], &rows)
    );
    println!("\nplus synthetics: chase-static chase-local \
              chase-interleaved chase-perthread");
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let machine = machine_flag(args)?;
    let w = workload_flag(args)?;
    let sim = sim_flag(args, machine);
    let pair = profile(&sim, &w);
    for (label, run) in [("symmetric", &pair.sym), ("asymmetric", &pair.asym)]
    {
        println!(
            "{label} run: threads {:?}, {:.2}s",
            run.threads_per_socket, run.counters.elapsed_s
        );
        for (b, bank) in run.counters.banks.iter().enumerate() {
            println!(
                "  bank {b}: local rd {} | remote rd {} | local wr {} | \
                 remote wr {}",
                report::fmt_bw(bank.local_read / run.counters.elapsed_s),
                report::fmt_bw(bank.remote_read / run.counters.elapsed_s),
                report::fmt_bw(bank.local_write / run.counters.elapsed_s),
                report::fmt_bw(bank.remote_write / run.counters.elapsed_s),
            );
        }
        println!(
            "  per-thread instr rates: {:?}",
            run.thread_rates()
                .iter()
                .map(|r| format!("{:.2e}", r))
                .collect::<Vec<_>>()
        );
    }
    Ok(())
}

fn cmd_fit(args: &Args) -> Result<()> {
    let machine = machine_flag(args)?;
    let w = workload_flag(args)?;
    let sim = sim_flag(args, machine);
    let svc = service_flag(args)?;
    let pair = profile(&sim, &w);
    let sig = &svc.fit(&[FitRequest {
        sym: pair.sym,
        asym: pair.asym,
    }])?[0];
    if let Some(path) = args.get("save") {
        let path = std::path::Path::new(path);
        let mut store = SignatureStore::load(path).unwrap_or_default();
        let seed = seed_flag(args);
        // Stamp the fit seed so store-backed serving can refuse to answer
        // for a differently-seeded world.  The seed metadata certifies
        // ALL of the machine's stored signatures, so any signature not
        // fitted under this seed — a different recorded seed, or a
        // legacy seed-less store — must be dropped before stamping, or
        // the guard would pass while serving stale models.
        let recorded = store.seed(&sim.machine.name);
        if recorded != Some(seed) {
            let dropped = store.remove_machine(&sim.machine.name);
            if dropped > 0 {
                let old = recorded
                    .map(|r| r.to_string())
                    .unwrap_or_else(|| "an unrecorded seed".to_string());
                println!(
                    "seed for {} is now {seed}; dropped {dropped} \
                     signature(s) fitted under {old}",
                    sim.machine.name
                );
            }
        }
        store.insert(&sim.machine.name, &w.name, *sig);
        store.set_seed(&sim.machine.name, seed);
        // Embed the topology so the store is portable: a host that has
        // neither the preset nor the @file can still serve this machine
        // by name.
        store.set_topology(&sim.machine.name, sim.machine.clone());
        store.save(path)?;
        println!("saved to {} ({} signatures)", path.display(), store.len());
    }
    println!("bandwidth signature for {} on {}:", w.name, sim.machine.name);
    for (ch, s) in [("read", &sig.read), ("write", &sig.write),
                    ("combined", &sig.combined)] {
        println!(
            "  {ch:<8} {} static={:.3}@{} local={:.3} perthread={:.3} \
             interleave={:.3} misfit={:.4}",
            report::signature_bar(s.static_frac, s.local_frac,
                                  s.perthread_frac, s.interleave_frac(), 32),
            s.static_frac, s.static_socket, s.local_frac, s.perthread_frac,
            s.interleave_frac(), s.misfit
        );
    }
    println!("  {}", misfit::describe(sig));
    Ok(())
}

/// Placement for `predict`: `--split a,b,..` (one count per socket) or
/// the 2-socket `--t0/--t1` shorthand.
fn split_flag(args: &Args) -> Result<Vec<usize>> {
    match args.get("split") {
        Some(spec) => spec
            .split(',')
            .map(|tok| {
                tok.trim().parse::<usize>().map_err(|_| {
                    anyhow!("--split: comma-separated thread counts, got \
                             {tok:?}")
                })
            })
            .collect(),
        None => Ok(vec![args.get_usize("t0", 1), args.get_usize("t1", 1)]),
    }
}

fn cmd_predict(args: &Args) -> Result<()> {
    let machine = machine_flag(args)?;
    let w = workload_flag(args)?;
    let split = split_flag(args)?;
    let sim = sim_flag(args, machine);
    // From a stored signature (no profiling) or a fresh two-run fit.
    let sig = if let Some(path) = args.get("store") {
        let store = SignatureStore::load(std::path::Path::new(path))?;
        *store.get(&sim.machine.name, &w.name).ok_or_else(|| {
            anyhow!("{path}: no signature for {}/{} — run `numabw fit \
                     --workload {} --machine {} --save {path}` first",
                    sim.machine.name, w.name, w.name,
                    args.get_or("machine", "xeon18"))
        })?
    } else {
        let svc = service_flag(args)?;
        let pair = profile(&sim, &w);
        svc.fit(&[FitRequest {
            sym: pair.sym,
            asym: pair.asym,
        }])?[0]
    };
    let sig = &sig;
    let placement = ThreadPlacement::new(split);
    placement.validate(&sim.machine).map_err(|e| anyhow!(e))?;
    println!(
        "predicted traffic fractions for {} with threads {:?}:",
        w.name, placement.threads_per_socket
    );
    for (ch, s) in [("read", &sig.read), ("write", &sig.write)] {
        let m = s.apply(&placement.threads_per_socket);
        println!("  {ch}:");
        for (src, row) in m.iter().enumerate() {
            println!(
                "    cpu{src} -> banks {:?}",
                row.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>()
            );
        }
    }
    Ok(())
}

/// Resolve the advise signature: fit-once-serve-forever through the model
/// registry when `--store` is given (seed-guarded), otherwise a fresh
/// profile + fit.
fn advise_signature(args: &Args, svc: &PredictionService, sim: &Simulator,
                    w: &WorkloadSpec) -> Result<BandwidthSignature> {
    let fit_fresh = || -> Result<BandwidthSignature> {
        let pair = profile(sim, w);
        Ok(svc
            .fit(&[FitRequest {
                sym: pair.sym,
                asym: pair.asym,
            }])?
            .pop()
            .expect("one signature per fit request"))
    };
    match args.get("store") {
        None => fit_fresh(),
        Some(path) => {
            let registry =
                ModelRegistry::open(std::path::Path::new(path))?;
            let known = registry.len();
            let sig = registry.get_or_fit_for(&sim.machine, &w.name,
                                              seed_flag(args), fit_fresh)?;
            println!(
                "signature for {}/{} served from store {path} ({})",
                sim.machine.name,
                w.name,
                if registry.len() > known {
                    "fitted now; future calls reuse it"
                } else {
                    "already fitted — no profiling run"
                }
            );
            Ok(*sig)
        }
    }
}

fn cmd_advise(args: &Args) -> Result<()> {
    let machine = machine_flag(args)?;
    let w = workload_flag(args)?;
    let sim = sim_flag(args, machine);
    let svc = service_flag(args)?;
    let total = args.get_usize("threads", sim.machine.cores_per_socket);
    let top = args.get_usize("top", 5).max(1);
    println!(
        "advising placement for `{}` with {total} threads on {} \
         (backend: {})\n",
        w.name,
        sim.machine.name,
        svc.backend_name()
    );
    let sig = advise_signature(args, &svc, &sim, &w)?;
    let advice = advisor::advise(&svc, &sim.machine, &w, &sig, total)?;
    let rows: Vec<Vec<String>> = advice
        .ranked
        .iter()
        .take(top)
        .map(|s| {
            vec![
                format!("{:?}", s.placement.threads_per_socket),
                report::fmt_bw(s.predicted_bw),
                format!("{:.0}%", 100.0 * s.satisfaction()),
                format!("{:.0}%", 100.0 * s.qpi_headroom),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(
            &["threads", "predicted bw", "satisfied", "qpi headroom"],
            &rows
        )
    );
    let best = advice.best();
    println!(
        "\nrecommended placement: {:?} — predicted {} ({} candidates \
         scored through the batched+cached path)",
        best.placement.threads_per_socket,
        report::fmt_bw(best.predicted_bw),
        advice.ranked.len()
    );
    println!("\nserving caches:");
    print!("{}", svc.cache_stats().table());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let svc = service_flag(args)?;
    let defaults = ServeOptions::default();
    let shards = args.get_usize("shards", defaults.shards);
    if !(1..=crate::obs::MAX_SHARDS).contains(&shards) {
        bail!(
            "--shards must be in 1..={}, got {shards}",
            crate::obs::MAX_SHARDS
        );
    }
    let workers = args.get_usize("workers", defaults.workers);
    if workers == 0 {
        bail!("--workers must be at least 1");
    }
    let opts = ServeOptions {
        store: args.get("store").map(std::path::PathBuf::from),
        seed: seed_flag(args),
        batch_size: args.get("batch").map(|b| {
            b.parse().expect("--batch: usize")
        }),
        window: std::time::Duration::from_micros(
            (args.get_f64("window-ms", 2.0) * 1000.0) as u64,
        ),
        trace_out: args.get("trace-out").map(std::path::PathBuf::from),
        metrics_dump: args
            .get("metrics-dump")
            .map(std::path::PathBuf::from),
        shards,
        workers,
    };
    if let Some(addr) = args.get("listen") {
        // Socket transports: TCP (`host:port`) or unix (`unix:/path`),
        // a fixed pool of --workers connection threads, all coalescing
        // into the same sharded front-end group.
        let listener = match addr.strip_prefix("unix:") {
            Some(path) => server::LineServer::start_unix(
                svc,
                opts,
                std::path::Path::new(path),
            )?,
            None => server::LineServer::start_tcp(svc, opts, addr)?,
        };
        eprintln!(
            "numabw serve: listening on {}",
            listener.endpoint_display()
        );
        return listener.run_forever();
    }
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let summary =
        server::serve_lines(svc, opts, stdin.lock(), &mut stdout.lock())?;
    eprintln!("{summary}");
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let machine = machine_flag(args)?;
    let sim = sim_flag(args, machine);
    let svc = service_flag(args)?;
    let ws = suite::table1();
    println!(
        "evaluating {} workloads on {} (backend: {}) ...",
        ws.len(),
        sim.machine.name,
        svc.backend_name()
    );
    let ev = evaluate_suite(&sim, &svc, &ws, None)?;
    let cdf = eval::error_cdf(&ev);
    println!("\n{} measurement points", ev.records.len());
    println!("median error: {:.2}% of total bandwidth", cdf.median());
    println!("fraction <= 2.5%: {:.1}%", 100.0 * cdf.at(2.5));
    println!("fraction <= 10%:  {:.1}%", 100.0 * cdf.at(10.0));
    println!("\nper-benchmark (Fig 18):");
    let rows: Vec<Vec<String>> = eval::accuracy_by_benchmark(&ev)
        .iter()
        .map(|r| {
            vec![
                r.workload.clone(),
                format!("{:.2}%", r.avg_err_pct),
                report::fmt_bw(r.avg_bandwidth),
                r.n_points.to_string(),
            ]
        })
        .collect();
    print!("{}", report::table(&["benchmark", "avg err", "avg bw",
                                 "points"], &rows));
    Ok(())
}

fn cmd_quickstart() -> Result<()> {
    let machine = MachineTopology::xeon_e5_2699_v3();
    let sim = Simulator::new(machine, SimConfig::default());
    let w = suite::by_name("cg").unwrap();
    let svc = PredictionService::reference();
    let pair = profile(&sim, &w);
    let sig = &svc.fit(&[FitRequest {
        sym: pair.sym,
        asym: pair.asym,
    }])?[0];
    println!("fitted signature for `cg` (read): static={:.2} local={:.2} \
              perthread={:.2} interleave={:.2}",
             sig.read.static_frac, sig.read.local_frac,
             sig.read.perthread_frac, sig.read.interleave_frac());
    let m = sig.read.apply(&[14, 4]);
    println!("traffic matrix for a (14, 4) placement: {m:?}");
    println!("run `numabw evaluate` for the full paper sweep");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn usage_on_no_command() {
        main_with(vec![]).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(main_with(toks("frobnicate")).is_err());
    }

    #[test]
    fn machines_and_workloads_render() {
        main_with(toks("machines")).unwrap();
        main_with(toks("workloads")).unwrap();
    }

    #[test]
    fn fit_runs_end_to_end() {
        main_with(toks("fit --workload cg --machine xeon8")).unwrap();
    }

    #[test]
    fn predict_validates_placement() {
        assert!(main_with(
            toks("predict --workload cg --t0 99 --t1 0 --machine xeon8")
        )
        .is_err());
        main_with(toks("predict --workload cg --t0 6 --t1 2 --machine xeon8"))
            .unwrap();
    }

    #[test]
    fn unknown_workload_errors() {
        assert!(main_with(toks("fit --workload nope")).is_err());
    }

    #[test]
    fn unknown_machine_error_lists_presets_and_file_form() {
        let err = main_with(toks("fit --workload cg --machine epyc"))
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unknown machine \"epyc\""), "{msg}");
        for name in ["xeon8", "xeon18", "quad4", "@<file.json>"] {
            assert!(msg.contains(name), "{msg} should list {name}");
        }
        // A missing topology file is a path error, not an unknown name.
        let err = main_with(toks(
            "fit --workload cg --machine @/no/such/topo.json"
        ))
        .unwrap_err();
        assert!(format!("{err}").contains("/no/such/topo.json"), "{err}");
    }

    #[test]
    fn discover_writes_a_file_the_machine_flag_loads() {
        use crate::topology::MachineTopology;
        let dir = std::env::temp_dir().join("numabw-cli-discover");
        let sys = dir.join("sys/devices/system/node");
        for (id, (dist, cpus)) in
            [("10 21", "0-7"), ("21 10", "8-15")].into_iter().enumerate()
        {
            let node = sys.join(format!("node{id}"));
            std::fs::create_dir_all(&node).unwrap();
            std::fs::write(node.join("distance"), format!("{dist}\n"))
                .unwrap();
            std::fs::write(node.join("cpulist"), format!("{cpus}\n"))
                .unwrap();
        }
        let out = dir.join("topo.json");
        std::fs::remove_file(&out).ok();
        main_with(toks(&format!(
            "discover --sysfs {} --name testbox --out {}",
            dir.join("sys").display(), out.display()
        )))
        .unwrap();
        // The written file loads through --machine @file and matches the
        // library-level discovery byte for byte.
        let loaded = crate::topology::file::load(&out).unwrap();
        assert_eq!(loaded.name, "testbox");
        assert_eq!(loaded.sockets, 2);
        main_with(toks(&format!(
            "advise --workload cg --machine @{} --threads 4 --top 2",
            out.display()
        )))
        .unwrap();
        // Stdout mode (no --out) also works against the mock root.
        main_with(toks(&format!(
            "discover --sysfs {}", dir.join("sys").display()
        )))
        .unwrap();
        // Preset twins: a round-tripped preset is == to its in-code twin.
        let preset = dir.join("xeon8.json");
        crate::topology::file::save(
            &MachineTopology::xeon_e5_2630_v3(), &preset).unwrap();
        assert_eq!(crate::topology::file::load(&preset).unwrap(),
                   MachineTopology::xeon_e5_2630_v3());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn advise_runs_end_to_end() {
        main_with(toks("advise --workload cg --machine xeon8 --top 3"))
            .unwrap();
        // Synthetic workloads are addressable too.
        main_with(toks(
            "advise --workload chase-static --machine xeon8 --threads 4"
        ))
        .unwrap();
    }

    #[test]
    fn native_engine_serves_every_machine_from_the_cli() {
        // The batched f32 engine behind --engine native: 2-socket fit +
        // advise, and the S-generic path on the synthetic quad machine
        // (the scenario the compiled 2-socket pipelines used to reject).
        main_with(toks(
            "fit --workload cg --machine xeon8 --engine native"
        ))
        .unwrap();
        main_with(toks(
            "advise --workload cg --machine xeon8 --top 3 --engine native"
        ))
        .unwrap();
        main_with(toks(
            "advise --workload cg --machine quad4 --threads 8 --top 3 \
             --engine native"
        ))
        .unwrap();
        // Unknown engines error cleanly.
        assert!(main_with(toks(
            "fit --workload cg --engine warp"
        ))
        .is_err());
    }

    #[test]
    fn hlo_engine_serves_from_the_cli() {
        // The restored `hlo` engine: fit + advise through the emitted
        // modules and the interpreter (S=2 keeps this test cheap; the
        // quad4 interpreter path runs release-mode in CI).
        main_with(toks("fit --workload cg --machine xeon8 --engine hlo"))
            .unwrap();
        main_with(toks(
            "advise --workload cg --machine xeon8 --top 3 --engine hlo"
        ))
        .unwrap();
        // The legacy alias still resolves (to the same backend).
        main_with(toks(
            "fit --workload cg --machine xeon8 --engine pjrt"
        ))
        .unwrap();
    }

    #[test]
    fn removed_and_misspelled_flags_are_rejected() {
        // `--hlo` predates the backend trait; silently ignoring it would
        // serve a different engine than the caller asked for.
        let err = main_with(toks("evaluate --machine xeon8 --hlo"))
            .unwrap_err();
        assert!(format!("{err}").contains("unknown flag --hlo"), "{err}");
        // Typos are caught, not dropped.
        let err = main_with(toks(
            "advise --workload cg --machine xeon8 --engne native"
        ))
        .unwrap_err();
        assert!(format!("{err}").contains("unknown flag --engne"),
                "{err}");
    }

    #[test]
    fn quad_socket_advise_and_predict_run_end_to_end() {
        // The S-socket serving path through the CLI: profile on the
        // 4-socket simulator, fit via fit_multi, rank all placements.
        main_with(toks(
            "advise --workload cg --machine quad4 --threads 8 --top 3"
        ))
        .unwrap();
        main_with(toks(
            "predict --workload cg --machine quad4 --split 4,2,1,1"
        ))
        .unwrap();
        // The 2-socket shorthand cannot describe a quad placement.
        assert!(main_with(toks(
            "predict --workload cg --machine quad4 --t0 4 --t1 4"
        ))
        .is_err());
        // Malformed split tokens error cleanly.
        assert!(main_with(toks(
            "predict --workload cg --machine quad4 --split 4,x,1,1"
        ))
        .is_err());
    }

    #[test]
    fn advise_rejects_oversized_thread_count() {
        assert!(main_with(toks(
            "advise --workload cg --machine xeon8 --threads 99"
        ))
        .is_err());
    }

    #[test]
    fn advise_store_fits_once_and_guards_seed() {
        let dir = std::env::temp_dir().join("numabw-cli-advise-store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sigs.json");
        std::fs::remove_file(&path).ok();
        let path_s = path.to_str().unwrap();
        // First call fits and persists; second serves from the store.
        main_with(toks(&format!(
            "advise --workload cg --machine xeon8 --top 2 --store {path_s}"
        )))
        .unwrap();
        assert!(path.exists());
        let before = std::fs::read(&path).unwrap();
        main_with(toks(&format!(
            "advise --workload cg --machine xeon8 --top 2 --store {path_s}"
        )))
        .unwrap();
        assert_eq!(before, std::fs::read(&path).unwrap(),
                   "serving from the store must not rewrite it");
        // A different seed is a different world: clean error.
        let err = main_with(toks(&format!(
            "advise --workload cg --machine xeon8 --top 2 \
             --store {path_s} --seed 99"
        )))
        .unwrap_err();
        assert!(format!("{err}").contains("seed"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fit_reseed_drops_stale_signatures() {
        let dir = std::env::temp_dir().join("numabw-cli-fit-reseed");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sigs.json");
        std::fs::remove_file(&path).ok();
        let path_s = path.to_str().unwrap();
        main_with(toks(&format!(
            "fit --workload cg --machine xeon8 --save {path_s}"
        )))
        .unwrap();
        // Re-fitting the machine under a new seed must drop the
        // old-world signatures, or the seed guard would pass while
        // serving stale models.
        main_with(toks(&format!(
            "fit --workload ft --machine xeon8 --save {path_s} --seed 99"
        )))
        .unwrap();
        let store = SignatureStore::load(&path).unwrap();
        assert!(store.get("xeon8", "cg").is_none(),
                "old-seed signature must be dropped");
        assert!(store.get("xeon8", "ft").is_some());
        assert_eq!(store.seed("xeon8"), Some(99));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn serve_cli_runs_a_transcript() {
        // The CLI wires stdin/stdout; drive the underlying loop directly.
        let input = "{\"id\":1,\"op\":\"stats\"}\n";
        let mut out = Vec::new();
        crate::server::serve_lines(
            PredictionService::reference(),
            crate::server::ServeOptions::default(),
            input.as_bytes(),
            &mut out,
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("\"ok\":true"), "{text}");
    }

    #[test]
    fn serve_flag_validation_rejects_bad_shards_and_workers() {
        // Validation fires before any transport (or stdin loop) starts.
        let err = main_with(toks("serve --shards 0")).unwrap_err();
        assert!(format!("{err}").contains("--shards"), "{err}");
        let err = main_with(toks("serve --shards 99")).unwrap_err();
        assert!(format!("{err}").contains("--shards"), "{err}");
        let err = main_with(toks("serve --workers 0")).unwrap_err();
        assert!(format!("{err}").contains("--workers"), "{err}");
    }

    #[test]
    fn engine_threads_flag_is_validated_and_accepted() {
        // 0 is rejected on every service-constructing subcommand path.
        let err =
            main_with(toks("serve --engine-threads 0")).unwrap_err();
        assert!(format!("{err}").contains("--engine-threads"), "{err}");
        // A pooled advise run end to end: the result path is pinned
        // bit-identical to serial by tests/engine_parity.rs; here the
        // flag just has to parse and serve.
        main_with(toks(
            "advise --workload cg --machine xeon8 --threads 4 --top 2 \
             --engine native --engine-threads 2",
        ))
        .unwrap();
    }

    #[test]
    fn store_roundtrip_via_cli() {
        let dir = std::env::temp_dir().join("numabw-cli-store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sigs.json");
        let path_s = path.to_str().unwrap();
        main_with(toks(&format!(
            "fit --workload ft --machine xeon8 --save {path_s}"
        )))
        .unwrap();
        // Prediction served from the store (no profiling).
        main_with(toks(&format!(
            "predict --workload ft --t0 6 --t1 2 --machine xeon8 \
             --store {path_s}"
        )))
        .unwrap();
        // Missing entry errors with guidance.
        assert!(main_with(toks(&format!(
            "predict --workload cg --t0 6 --t1 2 --machine xeon8 \
             --store {path_s}"
        )))
        .is_err());
        std::fs::remove_file(path).ok();
    }
}
