//! `numabw` command-line interface.
//!
//! Subcommands:
//!   machines   — list the built-in machine topologies (paper §2, Fig 2)
//!   workloads  — list the workload suite (paper Table 1)
//!   profile    — run the two §5.1 profiling runs for one workload
//!   fit        — profile + fit, print the bandwidth signature (§5)
//!   predict    — apply a fitted signature to a placement (§4)
//!   advise     — rank every thread placement (batched+cached serving)
//!   evaluate   — full measured-vs-predicted sweep (§6.2.2, Figs 16–18)
//!   quickstart — tiny end-to-end demo

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{
    advisor, evaluate_suite, profile, FitRequest, PredictionService,
    SignatureStore,
};
use crate::eval;
use crate::model::misfit;
use crate::report;
use crate::simulator::{SimConfig, Simulator, ThreadPlacement};
use crate::topology::MachineTopology;
use crate::util::args::Args;
use crate::workloads::{suite, synthetic, WorkloadSpec};

pub fn main_with(args: Vec<String>) -> Result<()> {
    let args = Args::parse(args);
    match args.command.as_deref() {
        Some("machines") => cmd_machines(),
        Some("workloads") => cmd_workloads(),
        Some("profile") => cmd_profile(&args),
        Some("fit") => cmd_fit(&args),
        Some("predict") => cmd_predict(&args),
        Some("advise") => cmd_advise(&args),
        Some("evaluate") => cmd_evaluate(&args),
        Some("quickstart") => cmd_quickstart(),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "\
numabw — NUMA bandwidth-pattern modeling (paper reproduction)

USAGE: numabw <subcommand> [flags]

  machines                          list machine topologies
  workloads                         list the Table-1 workload suite
  profile   --workload W [--machine M]       run the two §5.1 runs
  fit       --workload W [--machine M] [--hlo] [--save F]
                                    fit + print (optionally store) the
                                    signature
  predict   --workload W --t0 N --t1 N [--machine M] [--hlo] [--store F]
                                    predict a placement's traffic matrix
                                    (from a stored signature if --store)
  advise    --workload W [--machine M] [--threads N] [--top K] [--hlo]
                                    rank every valid thread placement by
                                    predicted bandwidth (Pandia-style;
                                    batched+cached serving path)
  evaluate  [--machine M] [--hlo] [--seed S]    full §6.2.2 sweep
  quickstart                        tiny end-to-end demo

Flags: --machine xeon8|xeon18 (default xeon18); --hlo uses the AOT PJRT
pipelines (default: Rust reference model); --seed u64.";

fn machine_flag(args: &Args) -> Result<MachineTopology> {
    let name = args.get_or("machine", "xeon18");
    MachineTopology::by_name(name)
        .ok_or_else(|| anyhow!("unknown machine {name:?} (xeon8|xeon18)"))
}

fn workload_flag(args: &Args) -> Result<WorkloadSpec> {
    let name = args
        .get("workload")
        .ok_or_else(|| anyhow!("--workload required"))?;
    suite::by_name(name)
        .or_else(|| {
            synthetic::all(0).into_iter().find(|w| w.name == name)
        })
        .ok_or_else(|| anyhow!("unknown workload {name:?} (see `numabw workloads`)"))
}

fn service_flag(args: &Args) -> PredictionService {
    if args.get_bool("hlo") {
        PredictionService::auto()
    } else {
        PredictionService::reference()
    }
}

fn sim_flag(args: &Args, machine: MachineTopology) -> Simulator {
    let seed = args.get("seed").map(|s| s.parse().expect("--seed: u64"));
    let mut cfg = SimConfig::default();
    if let Some(s) = seed {
        cfg = cfg.with_seed(s);
    }
    Simulator::new(machine, cfg)
}

fn cmd_machines() -> Result<()> {
    let rows: Vec<Vec<String>> = MachineTopology::paper_machines()
        .iter()
        .map(|m| {
            vec![
                m.name.clone(),
                format!("{}x{}", m.sockets, m.cores_per_socket),
                report::fmt_bw(m.local_read_bw),
                report::fmt_bw(m.local_write_bw),
                format!("{:.2}x", m.qpi_read_bw / m.local_read_bw),
                format!("{:.2}x", m.qpi_write_bw / m.local_write_bw),
                format!("${:.0}", m.price_usd),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(
            &["machine", "cores", "local rd", "local wr", "remote rd",
              "remote wr", "price/cpu"],
            &rows
        )
    );
    Ok(())
}

fn cmd_workloads() -> Result<()> {
    let rows: Vec<Vec<String>> = suite::table1()
        .iter()
        .map(|w| {
            vec![
                w.name.clone(),
                w.suite.tag().to_string(),
                w.description.clone(),
                format!("{:.2}", w.read_fraction),
                report::fmt_bw(w.bw_per_thread),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(&["name", "suite", "description", "rd frac",
                        "bw/thread"], &rows)
    );
    println!("\nplus synthetics: chase-static chase-local \
              chase-interleaved chase-perthread");
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let machine = machine_flag(args)?;
    let w = workload_flag(args)?;
    let sim = sim_flag(args, machine);
    let pair = profile(&sim, &w);
    for (label, run) in [("symmetric", &pair.sym), ("asymmetric", &pair.asym)]
    {
        println!(
            "{label} run: threads {:?}, {:.2}s",
            run.threads_per_socket, run.counters.elapsed_s
        );
        for (b, bank) in run.counters.banks.iter().enumerate() {
            println!(
                "  bank {b}: local rd {} | remote rd {} | local wr {} | \
                 remote wr {}",
                report::fmt_bw(bank.local_read / run.counters.elapsed_s),
                report::fmt_bw(bank.remote_read / run.counters.elapsed_s),
                report::fmt_bw(bank.local_write / run.counters.elapsed_s),
                report::fmt_bw(bank.remote_write / run.counters.elapsed_s),
            );
        }
        println!(
            "  per-thread instr rates: {:?}",
            run.thread_rates()
                .iter()
                .map(|r| format!("{:.2e}", r))
                .collect::<Vec<_>>()
        );
    }
    Ok(())
}

fn cmd_fit(args: &Args) -> Result<()> {
    let machine = machine_flag(args)?;
    let w = workload_flag(args)?;
    let sim = sim_flag(args, machine);
    let svc = service_flag(args);
    let pair = profile(&sim, &w);
    let sig = &svc.fit(&[FitRequest {
        sym: pair.sym,
        asym: pair.asym,
    }])?[0];
    if let Some(path) = args.get("save") {
        let path = std::path::Path::new(path);
        let mut store = SignatureStore::load(path).unwrap_or_default();
        store.insert(&sim.machine.name, &w.name, *sig);
        store.save(path)?;
        println!("saved to {} ({} signatures)", path.display(), store.len());
    }
    println!("bandwidth signature for {} on {}:", w.name, sim.machine.name);
    for (ch, s) in [("read", &sig.read), ("write", &sig.write),
                    ("combined", &sig.combined)] {
        println!(
            "  {ch:<8} {} static={:.3}@{} local={:.3} perthread={:.3} \
             interleave={:.3} misfit={:.4}",
            report::signature_bar(s.static_frac, s.local_frac,
                                  s.perthread_frac, s.interleave_frac(), 32),
            s.static_frac, s.static_socket, s.local_frac, s.perthread_frac,
            s.interleave_frac(), s.misfit
        );
    }
    println!("  {}", misfit::describe(sig));
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let machine = machine_flag(args)?;
    let w = workload_flag(args)?;
    let t0 = args.get_usize("t0", 1);
    let t1 = args.get_usize("t1", 1);
    let sim = sim_flag(args, machine);
    // From a stored signature (no profiling) or a fresh two-run fit.
    let sig = if let Some(path) = args.get("store") {
        let store = SignatureStore::load(std::path::Path::new(path))?;
        *store.get(&sim.machine.name, &w.name).ok_or_else(|| {
            anyhow!("{path}: no signature for {}/{} — run `numabw fit \
                     --workload {} --machine {} --save {path}` first",
                    sim.machine.name, w.name, w.name,
                    args.get_or("machine", "xeon18"))
        })?
    } else {
        let svc = service_flag(args);
        let pair = profile(&sim, &w);
        svc.fit(&[FitRequest {
            sym: pair.sym,
            asym: pair.asym,
        }])?[0]
    };
    let sig = &sig;
    let placement = ThreadPlacement::new(vec![t0, t1]);
    placement.validate(&sim.machine).map_err(|e| anyhow!(e))?;
    println!(
        "predicted traffic fractions for {} with threads ({t0}, {t1}):",
        w.name
    );
    for (ch, s) in [("read", &sig.read), ("write", &sig.write)] {
        let m = s.apply(&placement.threads_per_socket);
        println!("  {ch}:");
        for (src, row) in m.iter().enumerate() {
            println!(
                "    cpu{src} -> banks {:?}",
                row.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>()
            );
        }
    }
    Ok(())
}

fn cmd_advise(args: &Args) -> Result<()> {
    let machine = machine_flag(args)?;
    let w = workload_flag(args)?;
    let sim = sim_flag(args, machine);
    let svc = service_flag(args);
    let total = args.get_usize("threads", sim.machine.cores_per_socket);
    let top = args.get_usize("top", 5).max(1);
    println!(
        "advising placement for `{}` with {total} threads on {} \
         (backend: {})\n",
        w.name,
        sim.machine.name,
        if svc.is_hlo() { "HLO/PJRT" } else { "rust-reference" }
    );
    let advice = advisor::advise_workload(&svc, &sim, &w, Some(total))?;
    let rows: Vec<Vec<String>> = advice
        .ranked
        .iter()
        .take(top)
        .map(|s| {
            vec![
                format!("{:?}", s.placement.threads_per_socket),
                report::fmt_bw(s.predicted_bw),
                format!("{:.0}%", 100.0 * s.satisfaction()),
                format!("{:.0}%", 100.0 * s.qpi_headroom),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(
            &["threads", "predicted bw", "satisfied", "qpi headroom"],
            &rows
        )
    );
    let best = advice.best();
    println!(
        "\nrecommended placement: {:?} — predicted {} ({} candidates \
         scored through the batched+cached path)",
        best.placement.threads_per_socket,
        report::fmt_bw(best.predicted_bw),
        advice.ranked.len()
    );
    let stats = svc.cache_stats();
    println!("serving cache: {} hits / {} misses", stats.hits,
             stats.misses);
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let machine = machine_flag(args)?;
    let sim = sim_flag(args, machine);
    let svc = service_flag(args);
    let ws = suite::table1();
    println!(
        "evaluating {} workloads on {} (backend: {}) ...",
        ws.len(),
        sim.machine.name,
        if svc.is_hlo() { "HLO/PJRT" } else { "rust-reference" }
    );
    let ev = evaluate_suite(&sim, &svc, &ws, None)?;
    let cdf = eval::error_cdf(&ev);
    println!("\n{} measurement points", ev.records.len());
    println!("median error: {:.2}% of total bandwidth", cdf.median());
    println!("fraction <= 2.5%: {:.1}%", 100.0 * cdf.at(2.5));
    println!("fraction <= 10%:  {:.1}%", 100.0 * cdf.at(10.0));
    println!("\nper-benchmark (Fig 18):");
    let rows: Vec<Vec<String>> = eval::accuracy_by_benchmark(&ev)
        .iter()
        .map(|r| {
            vec![
                r.workload.clone(),
                format!("{:.2}%", r.avg_err_pct),
                report::fmt_bw(r.avg_bandwidth),
                r.n_points.to_string(),
            ]
        })
        .collect();
    print!("{}", report::table(&["benchmark", "avg err", "avg bw",
                                 "points"], &rows));
    Ok(())
}

fn cmd_quickstart() -> Result<()> {
    let machine = MachineTopology::xeon_e5_2699_v3();
    let sim = Simulator::new(machine, SimConfig::default());
    let w = suite::by_name("cg").unwrap();
    let svc = PredictionService::reference();
    let pair = profile(&sim, &w);
    let sig = &svc.fit(&[FitRequest {
        sym: pair.sym,
        asym: pair.asym,
    }])?[0];
    println!("fitted signature for `cg` (read): static={:.2} local={:.2} \
              perthread={:.2} interleave={:.2}",
             sig.read.static_frac, sig.read.local_frac,
             sig.read.perthread_frac, sig.read.interleave_frac());
    let m = sig.read.apply(&[14, 4]);
    println!("traffic matrix for a (14, 4) placement: {m:?}");
    println!("run `numabw evaluate` for the full paper sweep");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn usage_on_no_command() {
        main_with(vec![]).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(main_with(toks("frobnicate")).is_err());
    }

    #[test]
    fn machines_and_workloads_render() {
        main_with(toks("machines")).unwrap();
        main_with(toks("workloads")).unwrap();
    }

    #[test]
    fn fit_runs_end_to_end() {
        main_with(toks("fit --workload cg --machine xeon8")).unwrap();
    }

    #[test]
    fn predict_validates_placement() {
        assert!(main_with(
            toks("predict --workload cg --t0 99 --t1 0 --machine xeon8")
        )
        .is_err());
        main_with(toks("predict --workload cg --t0 6 --t1 2 --machine xeon8"))
            .unwrap();
    }

    #[test]
    fn unknown_workload_errors() {
        assert!(main_with(toks("fit --workload nope")).is_err());
    }

    #[test]
    fn advise_runs_end_to_end() {
        main_with(toks("advise --workload cg --machine xeon8 --top 3"))
            .unwrap();
        // Synthetic workloads are addressable too.
        main_with(toks(
            "advise --workload chase-static --machine xeon8 --threads 4"
        ))
        .unwrap();
    }

    #[test]
    fn advise_rejects_oversized_thread_count() {
        assert!(main_with(toks(
            "advise --workload cg --machine xeon8 --threads 99"
        ))
        .is_err());
    }

    #[test]
    fn store_roundtrip_via_cli() {
        let dir = std::env::temp_dir().join("numabw-cli-store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sigs.json");
        let path_s = path.to_str().unwrap();
        main_with(toks(&format!(
            "fit --workload ft --machine xeon8 --save {path_s}"
        )))
        .unwrap();
        // Prediction served from the store (no profiling).
        main_with(toks(&format!(
            "predict --workload ft --t0 6 --t1 2 --machine xeon8 \
             --store {path_s}"
        )))
        .unwrap();
        // Missing entry errors with guidance.
        assert!(main_with(toks(&format!(
            "predict --workload cg --t0 6 --t1 2 --machine xeon8 \
             --store {path_s}"
        )))
        .is_err());
        std::fs::remove_file(path).ok();
    }
}
