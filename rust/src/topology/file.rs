//! Versioned on-disk topology file format.
//!
//! A topology file is one JSON object (conventionally one line, as written
//! by [`save`] and `numabw discover --out`):
//!
//! ```json
//! {"attrs":{"cache_kb":[32,32,1024],"node_mem_mb":[32768,32768],
//!           "page_kb":[4,2048]},
//!  "chan_read_bw":[44000000000,44000000000],
//!  "chan_write_bw":[30000000000,30000000000],
//!  "core_peak_bw":5500000000,"cores_per_socket":8,
//!  "distance":[[10,21],[21,10]],
//!  "format":"numabw-topology",
//!  "latency_ns":[[90,200],[200,90]],
//!  "link_read_bw":[[0,7040000000],[7040000000,0]],
//!  "link_write_bw":[[0,6900000000],[6900000000,0]],
//!  "name":"my-box","price_usd":667,"sockets":2,"version":1}
//! ```
//!
//! Matrices (`distance`, `latency_ns`, and both link capacities) are S×S
//! nested arrays for hand-editability; link diagonals must be exactly `0`
//! (a socket has no link to itself) and are dropped when decoding into the
//! dense per-directed-link vectors.  Keys encode in sorted order
//! (`util::json` objects are BTreeMap-backed), so encode→decode→encode is
//! byte-identical — stores embedding a topology stay byte-deterministic.
//!
//! Decoding is strict, in the spirit of the wire-protocol integer fixes:
//! counted fields (`sockets`, `cores_per_socket`, `version`, distance
//! entries) reject fractional and negative values outright, matrix shape
//! errors name the offending row, and every successfully parsed topology
//! still has to pass [`MachineTopology::validate`].

use std::path::Path;

use crate::topology::MachineTopology;
use crate::topology::TopologyAttrs;
use crate::util::json::Json;

/// Format marker stored in every topology file.
pub const FORMAT: &str = "numabw-topology";

/// Current file-format version (bump on incompatible schema changes).
pub const VERSION: u64 = 1;

fn matrix_json(s: usize, at: impl Fn(usize, usize) -> Json) -> Json {
    Json::Arr((0..s).map(|i| {
        Json::Arr((0..s).map(|j| at(i, j)).collect())
    }).collect())
}

/// Encode a topology as the versioned file JSON.
pub fn to_json(t: &MachineTopology) -> Json {
    let s = t.sockets;
    let mut j = Json::obj();
    j.set("format", Json::Str(FORMAT.to_string()));
    j.set("version", Json::from_u64(VERSION));
    j.set("name", Json::Str(t.name.clone()));
    j.set("sockets", Json::from_u64(s as u64));
    j.set("cores_per_socket", Json::from_u64(t.cores_per_socket as u64));
    j.set("chan_read_bw", Json::from_f64_slice(&t.chan_read_bw));
    j.set("chan_write_bw", Json::from_f64_slice(&t.chan_write_bw));
    j.set("link_read_bw", matrix_json(s, |i, k| {
        Json::Num(if i == k { 0.0 } else { t.link_read_cap(i, k) })
    }));
    j.set("link_write_bw", matrix_json(s, |i, k| {
        Json::Num(if i == k { 0.0 } else { t.link_write_cap(i, k) })
    }));
    j.set("distance", matrix_json(s, |i, k| {
        Json::from_u64(t.node_distance[i * s + k] as u64)
    }));
    j.set("latency_ns", matrix_json(s, |i, k| {
        Json::Num(t.latency_matrix_ns[i * s + k])
    }));
    j.set("core_peak_bw", Json::Num(t.core_peak_bw));
    j.set("price_usd", Json::Num(t.price_usd));
    if !t.attrs.is_empty() {
        let mut a = Json::obj();
        if !t.attrs.node_mem_mb.is_empty() {
            a.set("node_mem_mb", Json::Arr(
                t.attrs.node_mem_mb.iter().map(|&v| Json::from_u64(v))
                    .collect()));
        }
        if !t.attrs.cache_kb.is_empty() {
            a.set("cache_kb", Json::Arr(
                t.attrs.cache_kb.iter().map(|&v| Json::from_u64(v))
                    .collect()));
        }
        if !t.attrs.page_kb.is_empty() {
            a.set("page_kb", Json::Arr(
                t.attrs.page_kb.iter().map(|&v| Json::from_u64(v))
                    .collect()));
        }
        j.set("attrs", a);
    }
    j
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key)
        .ok_or_else(|| format!("topology file: missing field {key:?}"))
}

/// Counted field: reject fractional and negative values outright (the
/// PR 2 / PR 4 wire-fix idiom) rather than truncating.
fn req_u64(j: &Json, key: &str) -> Result<u64, String> {
    req(j, key)?.as_u64().ok_or_else(|| {
        format!("topology file: field {key:?} must hold a non-negative \
                 integer")
    })
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
    req(j, key)?.as_str().ok_or_else(|| {
        format!("topology file: field {key:?} must be a string")
    })
}

fn req_f64(j: &Json, key: &str) -> Result<f64, String> {
    req(j, key)?.as_f64().ok_or_else(|| {
        format!("topology file: field {key:?} must be a number")
    })
}

fn req_f64_vec(j: &Json, key: &str, want: usize) -> Result<Vec<f64>, String> {
    let v = req(j, key)?.as_f64_vec().ok_or_else(|| {
        format!("topology file: field {key:?} must be an array of numbers")
    })?;
    if v.len() != want {
        return Err(format!(
            "topology file: field {key:?} must have one entry per socket \
             (expected {want}, got {})", v.len()
        ));
    }
    Ok(v)
}

/// S×S nested matrix of numbers, row-major flattening.
fn req_matrix(j: &Json, key: &str, s: usize) -> Result<Vec<f64>, String> {
    let rows = req(j, key)?.as_arr().ok_or_else(|| {
        format!("topology file: field {key:?} must be a {s}x{s} matrix")
    })?;
    if rows.len() != s {
        return Err(format!(
            "topology file: field {key:?} must be a {s}x{s} matrix \
             (got {} rows)", rows.len()
        ));
    }
    let mut flat = Vec::with_capacity(s * s);
    for (i, row) in rows.iter().enumerate() {
        let vals = row.as_f64_vec().ok_or_else(|| {
            format!("topology file: {key}[{i}] must be an array of numbers")
        })?;
        if vals.len() != s {
            return Err(format!(
                "topology file: field {key:?} must be a {s}x{s} matrix \
                 (row {i} has {} entries)", vals.len()
            ));
        }
        flat.extend(vals);
    }
    Ok(flat)
}

/// Dense per-directed-link vector from an S×S matrix whose diagonal must
/// be exactly zero.
fn links_from_matrix(key: &str, s: usize, flat: &[f64])
    -> Result<Vec<f64>, String>
{
    let mut links = Vec::with_capacity(s * (s - 1));
    for i in 0..s {
        for k in 0..s {
            let v = flat[i * s + k];
            if i == k {
                if v != 0.0 {
                    return Err(format!(
                        "topology file: {key}[{i}][{i}] must be 0 — a \
                         socket has no link to itself (got {v})"
                    ));
                }
            } else {
                links.push(v);
            }
        }
    }
    Ok(links)
}

fn opt_u64_vec(j: &Json, key: &str) -> Result<Vec<u64>, String> {
    match j.get(key) {
        None => Ok(Vec::new()),
        Some(arr) => {
            let items = arr.as_arr().ok_or_else(|| {
                format!("topology file: attrs.{key} must be an array")
            })?;
            items.iter().map(|v| v.as_u64().ok_or_else(|| {
                format!("topology file: attrs.{key} entries must be \
                         non-negative integers")
            })).collect()
        }
    }
}

/// Decode (and validate) a topology from its file JSON.
pub fn from_json(j: &Json) -> Result<MachineTopology, String> {
    match j.get("format").and_then(Json::as_str) {
        Some(f) if f == FORMAT => {}
        _ => {
            return Err(format!(
                "topology file: missing or wrong \"format\" marker \
                 (expected {FORMAT:?})"
            ));
        }
    }
    let version = req_u64(j, "version")?;
    if version != VERSION {
        return Err(format!(
            "topology file: unsupported version {version} (this build \
             reads version {VERSION})"
        ));
    }
    let name = req_str(j, "name")?.to_string();
    let sockets = req_u64(j, "sockets")? as usize;
    let cores_per_socket = req_u64(j, "cores_per_socket")? as usize;
    if sockets < 2 {
        return Err(format!(
            "topology {name:?}: need >= 2 sockets (got {sockets}; a \
             single-socket box has no interconnect to model)"
        ));
    }
    let s = sockets;
    let chan_read_bw = req_f64_vec(j, "chan_read_bw", s)?;
    let chan_write_bw = req_f64_vec(j, "chan_write_bw", s)?;
    let link_read_bw =
        links_from_matrix("link_read_bw", s,
                          &req_matrix(j, "link_read_bw", s)?)?;
    let link_write_bw =
        links_from_matrix("link_write_bw", s,
                          &req_matrix(j, "link_write_bw", s)?)?;
    let distance_f = req_matrix(j, "distance", s)?;
    let mut node_distance = Vec::with_capacity(s * s);
    for (i, d) in distance_f.iter().enumerate() {
        if d.fract() != 0.0 || *d < 0.0 || *d > u32::MAX as f64 {
            return Err(format!(
                "topology file: distance[{}][{}] must be a non-negative \
                 integer (got {d})", i / s, i % s
            ));
        }
        node_distance.push(*d as u32);
    }
    let latency_matrix_ns = req_matrix(j, "latency_ns", s)?;
    let core_peak_bw = req_f64(j, "core_peak_bw")?;
    let price_usd = req_f64(j, "price_usd")?;
    let attrs = match j.get("attrs") {
        None => TopologyAttrs::default(),
        Some(a) => TopologyAttrs {
            node_mem_mb: opt_u64_vec(a, "node_mem_mb")?,
            cache_kb: opt_u64_vec(a, "cache_kb")?,
            page_kb: opt_u64_vec(a, "page_kb")?,
        },
    };
    let t = MachineTopology {
        name,
        sockets,
        cores_per_socket,
        chan_read_bw,
        chan_write_bw,
        link_read_bw,
        link_write_bw,
        node_distance,
        latency_matrix_ns,
        core_peak_bw,
        price_usd,
        attrs,
    };
    t.validate()?;
    Ok(t)
}

/// Write a topology file: the sorted-key JSON encoding plus a trailing
/// newline (byte-deterministic — what the CI golden diff pins).
pub fn save(t: &MachineTopology, path: &Path) -> Result<(), String> {
    let text = to_json(t).encode() + "\n";
    std::fs::write(path, text)
        .map_err(|e| format!("topology file {}: {e}", path.display()))
}

/// Load and validate a topology file.
pub fn load(path: &Path) -> Result<MachineTopology, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("topology file {}: {e}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| format!("topology file {}: {e}", path.display()))?;
    from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
}

/// Resolve a `--machine` / wire `machine` spec: `@path.json` loads a
/// topology file, anything else must be a preset name.  The error for an
/// unknown name lists every accepted spelling (the satellite bugfix for
/// the old bare `unknown machine` message).
pub fn resolve_machine(spec: &str) -> Result<MachineTopology, String> {
    if let Some(path) = spec.strip_prefix('@') {
        if path.is_empty() {
            return Err("machine spec \"@\" is missing a file path \
                        (expected @topology.json)".to_string());
        }
        return load(Path::new(path));
    }
    MachineTopology::by_name(spec)
        .ok_or_else(|| unknown_machine_error(spec))
}

/// Typed unknown-machine error listing the presets and the `@file.json`
/// form.  Shared by the CLI flag parser and the wire-protocol `machine`
/// field.
pub fn unknown_machine_error(spec: &str) -> String {
    let presets = MachineTopology::preset_names()
        .iter()
        .map(|(short, long)| format!("{short} ({long})"))
        .collect::<Vec<_>>()
        .join(", ");
    format!(
        "unknown machine {spec:?}: available presets are {presets}; a \
         topology file can be used as @<file.json> (`numabw discover` \
         writes one)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_encode_is_byte_identical() {
        for m in MachineTopology::builtin_machines() {
            let first = to_json(&m).encode();
            let back = from_json(&Json::parse(&first).unwrap()).unwrap();
            assert_eq!(back, m);
            assert_eq!(to_json(&back).encode(), first, "{}", m.name);
        }
    }

    #[test]
    fn attrs_roundtrip_and_omission() {
        let mut m = MachineTopology::xeon_e5_2630_v3();
        assert!(!to_json(&m).encode().contains("attrs"));
        m.attrs.node_mem_mb = vec![32768, 32768];
        m.attrs.cache_kb = vec![32, 32, 1024, 25344];
        m.attrs.page_kb = vec![4, 2048];
        let j = to_json(&m);
        let back = from_json(&j).unwrap();
        assert_eq!(back, m);
        assert_eq!(to_json(&back).encode(), j.encode());
    }

    #[test]
    fn rejects_unknown_version() {
        let mut j = to_json(&MachineTopology::xeon_e5_2630_v3());
        j.set("version", Json::Num(2.0));
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("unsupported version 2"), "{err}");
        j.set("version", Json::Num(1.5));
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("non-negative integer"), "{err}");
    }

    #[test]
    fn rejects_missing_format_marker() {
        let j = Json::parse(r#"{"version":1}"#).unwrap();
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("format"), "{err}");
    }

    #[test]
    fn rejects_fractional_and_negative_counts() {
        let mut j = to_json(&MachineTopology::xeon_e5_2630_v3());
        j.set("sockets", Json::Num(2.5));
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("\"sockets\"") && err.contains("integer"),
                "{err}");
        let mut j = to_json(&MachineTopology::xeon_e5_2630_v3());
        j.set("cores_per_socket", Json::Num(-8.0));
        assert!(from_json(&j).unwrap_err().contains("integer"));
    }

    #[test]
    fn rejects_wrong_matrix_shape() {
        let mut j = to_json(&MachineTopology::xeon_e5_2630_v3());
        j.set("latency_ns",
              Json::parse("[[90,200],[200,90],[1,2]]").unwrap());
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("2x2") && err.contains("3 rows"), "{err}");
        let mut j = to_json(&MachineTopology::xeon_e5_2630_v3());
        j.set("distance", Json::parse("[[10,21],[21]]").unwrap());
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("row 1 has 1 entries"), "{err}");
    }

    #[test]
    fn rejects_nonzero_link_diagonal_and_fractional_distance() {
        let mut j = to_json(&MachineTopology::xeon_e5_2630_v3());
        j.set("link_read_bw",
              Json::parse("[[1,7e9],[7e9,0]]").unwrap());
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("link_read_bw[0][0]"), "{err}");
        let mut j = to_json(&MachineTopology::xeon_e5_2630_v3());
        j.set("distance", Json::parse("[[10,21.5],[21,10]]").unwrap());
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("distance[0][1]"), "{err}");
    }

    #[test]
    fn rejects_negative_capacity_via_validate() {
        let mut j = to_json(&MachineTopology::xeon_e5_2630_v3());
        j.set("chan_read_bw", Json::parse("[-1,44e9]").unwrap());
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("chan_read_bw") && err.contains("positive"),
                "{err}");
    }

    #[test]
    fn resolve_machine_handles_presets_and_unknown_names() {
        let m = resolve_machine("xeon8").unwrap();
        assert_eq!(m, MachineTopology::xeon_e5_2630_v3());
        let err = resolve_machine("epyc").unwrap_err();
        assert!(err.contains("unknown machine \"epyc\""), "{err}");
        assert!(err.contains("xeon8") && err.contains("xeon18")
                && err.contains("quad4"), "{err}");
        assert!(err.contains("@<file.json>"), "{err}");
        assert!(resolve_machine("@").unwrap_err().contains("file path"));
    }
}
