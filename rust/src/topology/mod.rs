//! Machine topology: the NUMA hardware description the simulator executes
//! on and the model predicts for (paper §2, Figs 2–3) — **as data, not
//! code**.
//!
//! A machine has `sockets` sockets, each with `cores_per_socket` cores and
//! a directly-attached memory bank reached over a memory channel; sockets
//! are joined by point-to-point interconnect links (QPI on the paper's
//! Xeons).  Capacities are expressed in bytes/second; latencies in
//! nanoseconds.  Every hardware parameter is **per resource**:
//!
//! * `chan_read_bw` / `chan_write_bw` — one channel capacity per socket;
//! * `link_read_bw` / `link_write_bw` — one capacity per *directed*
//!   interconnect link (dense over ordered socket pairs, see
//!   [`MachineTopology::link_offset`]);
//! * `node_distance` — the S×S ACPI-SLIT-style node-distance matrix
//!   (sysfs `node*/distance`; the diagonal is the local distance,
//!   canonically 10);
//! * `latency_matrix_ns` — the S×S load-to-use latency matrix that
//!   [`MachineTopology::latency_ns`] reads.  Discovery seeds it from
//!   distance ratios; presets pin the paper's measured local/remote pair.
//!
//! This makes asymmetric machines — sub-NUMA clusters, heterogeneous
//! links, distance matrices no local/remote scalar pair can express —
//! first-class: every engine consumes [`MachineTopology::capacities`] and
//! the latency matrix, so asymmetry flows through fit, advice, and serving
//! with no engine changes.  The three presets are built through
//! [`MachineTopology::uniform`] and produce bit-identical capacity vectors
//! to the pre-refactor scalar model.
//!
//! Topologies serialize to a versioned JSON file format ([`file`]) and can
//! be discovered from a live Linux box's sysfs ([`discover`], `numabw
//! discover`).  `--machine` flags and wire-protocol `machine` fields
//! accept `@path.json` alongside preset names.
//!
//! Read and write interconnect capacities are modeled as separate
//! resources because the paper's Fig 2 measures them separately and finds
//! very different ratios (8-core: remote read 0.16× local vs remote write
//! 0.23×; 18-core: 0.59× vs 0.83×).

pub mod discover;
pub mod file;

use crate::util::json::Json;

/// Gigabyte per second in bytes/second.
pub const GB: f64 = 1e9;

/// The canonical ACPI SLIT local node distance (what Linux reports on the
/// diagonal of `/sys/devices/system/node/node*/distance`).
pub const LOCAL_DISTANCE: u32 = 10;

/// Resource footprint of performance-query flow `(src, dst, rw)` on an
/// S-socket machine (flow order `(src*S + dst)*2 + rw`, the S-socket
/// generalisation of `model.py build_incidence`'s 2-socket
/// `src*4 + dst*2 + rw`): the memory channel at the destination bank, plus
/// the interconnect link for remote flows — read data crosses the
/// `dst -> src` read link, write data the `src -> dst` write link.
/// Index arithmetic matches [`MachineTopology::read_chan`] /
/// [`MachineTopology::write_chan`] / [`MachineTopology::qpi_read_link`] /
/// [`MachineTopology::qpi_write_link`].  Single source of truth shared by
/// the reference `predict_performance`, the advisor's headroom accounting,
/// and the runtime's synthesized flow→resource incidence
/// ([`crate::runtime::Artifacts::synthesize`]).
pub fn flow_resources(sockets: usize, src: usize, dst: usize,
                      rw: usize) -> (usize, Option<usize>) {
    let s = sockets;
    // Dense index over ordered pairs (a, b), a != b (row-major, matching
    // MachineTopology::link_offset).
    let off = |a: usize, b: usize| {
        a * (s - 1) + if b > a { b - 1 } else { b }
    };
    let chan = if rw == 0 { dst } else { s + dst };
    let link = if src != dst {
        Some(if rw == 0 {
            2 * s + off(dst, src)
        } else {
            2 * s + s * (s - 1) + off(src, dst)
        })
    } else {
        None
    };
    (chan, link)
}

/// Inert descriptive attributes riding along on a topology: recorded by
/// discovery, persisted in topology files, never consumed by the model.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct TopologyAttrs {
    /// Per-socket memory size in MB (empty = unknown).
    pub node_mem_mb: Vec<u64>,
    /// Cache hierarchy sizes in KB, innermost level first (empty =
    /// unknown).
    pub cache_kb: Vec<u64>,
    /// Supported page sizes in KB (empty = unknown).
    pub page_kb: Vec<u64>,
}

impl TopologyAttrs {
    pub fn is_empty(&self) -> bool {
        self.node_mem_mb.is_empty()
            && self.cache_kb.is_empty()
            && self.page_kb.is_empty()
    }
}

/// Description of one NUMA machine, with per-socket and per-directed-link
/// hardware parameters.  Uniform machines (every socket and link alike)
/// come from [`MachineTopology::uniform`]; asymmetric ones from topology
/// files ([`file`]) or sysfs discovery ([`discover`]).
#[derive(Clone, Debug, PartialEq)]
pub struct MachineTopology {
    pub name: String,
    pub sockets: usize,
    pub cores_per_socket: usize,
    /// Local memory-channel read capacity per socket (bytes/s, len S).
    pub chan_read_bw: Vec<f64>,
    /// Local memory-channel write capacity per socket (bytes/s, len S).
    pub chan_write_bw: Vec<f64>,
    /// Interconnect read capacity per directed link (bytes/s): the rate at
    /// which read *data* can cross from one socket's bank to another's
    /// CPU.  Dense over ordered pairs `(src, dst), src != dst`, row-major
    /// (len `S*(S-1)`, indexed by [`MachineTopology::link_offset`]).
    pub link_read_bw: Vec<f64>,
    /// Interconnect write capacity per directed link (bytes/s, same
    /// order).
    pub link_write_bw: Vec<f64>,
    /// S×S node-distance matrix, row-major (sysfs / ACPI SLIT convention:
    /// the diagonal is the local distance, canonically
    /// [`LOCAL_DISTANCE`]).
    pub node_distance: Vec<u32>,
    /// S×S load-to-use latency matrix (ns), row-major: entry
    /// `src*S + dst` is what a thread on `src` sees against bank `dst`.
    pub latency_matrix_ns: Vec<f64>,
    /// Peak memory demand a single core can generate against an idle local
    /// bank (bytes/s) — the CPU-side issue limit that makes the 18-core
    /// machine "CPU-bound and forgiving" in Fig 1.
    pub core_peak_bw: f64,
    /// Suggested retail price per CPU, USD (the paper's cost argument).
    pub price_usd: f64,
    /// Inert metadata (cache hierarchy, page sizes, per-node memory).
    pub attrs: TopologyAttrs,
}

impl MachineTopology {
    pub fn total_cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }

    /// Number of contention resources: one read + one write channel per
    /// socket, plus read and write capacities for each directed
    /// interconnect link.
    pub fn n_resources(&self) -> usize {
        2 * self.sockets + 2 * self.sockets * (self.sockets - 1)
    }

    /// Resource index of socket `s`'s channel. Layout (matching the Python
    /// model for S=2): `[read_chan..., write_chan..., qpi_r links...,
    /// qpi_w links...]` with links ordered by `(src, dst), src != dst`,
    /// row-major.  Out-of-range socket indices are a hard error in every
    /// build profile (not just debug) — a silently-wrong resource index
    /// would corrupt the contention solve.
    pub fn read_chan(&self, s: usize) -> usize {
        assert!(s < self.sockets,
                "socket index {s} out of range on {}-socket machine {:?}",
                self.sockets, self.name);
        s
    }

    pub fn write_chan(&self, s: usize) -> usize {
        assert!(s < self.sockets,
                "socket index {s} out of range on {}-socket machine {:?}",
                self.sockets, self.name);
        self.sockets + s
    }

    /// Dense index of directed link `(src, dst)` over ordered pairs,
    /// `src != dst`, row-major — the order `link_read_bw` /
    /// `link_write_bw` are stored in.
    pub fn link_offset(&self, src: usize, dst: usize) -> usize {
        assert!(src != dst,
                "link ({src}, {dst}): a socket has no link to itself");
        assert!(src < self.sockets && dst < self.sockets,
                "link ({src}, {dst}) out of range on {}-socket machine {:?}",
                self.sockets, self.name);
        src * (self.sockets - 1) + if dst > src { dst - 1 } else { dst }
    }

    pub fn qpi_read_link(&self, src: usize, dst: usize) -> usize {
        2 * self.sockets + self.link_offset(src, dst)
    }

    pub fn qpi_write_link(&self, src: usize, dst: usize) -> usize {
        2 * self.sockets
            + self.sockets * (self.sockets - 1)
            + self.link_offset(src, dst)
    }

    /// Capacity vector over all resources (order per the index functions).
    /// The single source of truth every engine consumes — per-socket and
    /// per-link asymmetry flows through fit/advise/serve via this vector.
    pub fn capacities(&self) -> Vec<f64> {
        let mut caps = Vec::with_capacity(self.n_resources());
        caps.extend_from_slice(&self.chan_read_bw);
        caps.extend_from_slice(&self.chan_write_bw);
        caps.extend_from_slice(&self.link_read_bw);
        caps.extend_from_slice(&self.link_write_bw);
        caps
    }

    /// Latency seen by a thread on `src` accessing bank `dst` (the S×S
    /// latency matrix, driven by the node-distance matrix for discovered
    /// topologies).
    pub fn latency_ns(&self, src: usize, dst: usize) -> f64 {
        assert!(src < self.sockets && dst < self.sockets,
                "latency ({src}, {dst}) out of range on {}-socket machine \
                 {:?}", self.sockets, self.name);
        self.latency_matrix_ns[src * self.sockets + dst]
    }

    /// Best-case local latency (ns): the smallest diagonal entry of the
    /// latency matrix.  The issue-rate model's reference scale — on a
    /// uniform machine this is *the* local latency.
    pub fn local_latency_ns(&self) -> f64 {
        (0..self.sockets)
            .map(|s| self.latency_matrix_ns[s * self.sockets + s])
            .fold(f64::INFINITY, f64::min)
    }

    /// Node distance between `src` and `dst` (SLIT convention).
    pub fn distance(&self, src: usize, dst: usize) -> u32 {
        assert!(src < self.sockets && dst < self.sockets,
                "distance ({src}, {dst}) out of range on {}-socket machine \
                 {:?}", self.sockets, self.name);
        self.node_distance[src * self.sockets + dst]
    }

    /// Local read-channel capacity of socket `s` (bytes/s).
    pub fn chan_read_cap(&self, s: usize) -> f64 {
        self.chan_read_bw[self.read_chan(s)]
    }

    /// Local write-channel capacity of socket `s` (bytes/s).
    pub fn chan_write_cap(&self, s: usize) -> f64 {
        let i = self.read_chan(s); // bounds check; write vec is socket-indexed
        self.chan_write_bw[i]
    }

    /// Read capacity of directed interconnect link `(src, dst)` (bytes/s).
    pub fn link_read_cap(&self, src: usize, dst: usize) -> f64 {
        self.link_read_bw[self.link_offset(src, dst)]
    }

    /// Write capacity of directed interconnect link `(src, dst)`
    /// (bytes/s).
    pub fn link_write_cap(&self, src: usize, dst: usize) -> f64 {
        self.link_write_bw[self.link_offset(src, dst)]
    }

    /// Uniform convenience constructor: every socket gets the same channel
    /// capacities, every directed link the same interconnect capacities,
    /// the latency matrix is local on the diagonal and remote off it, and
    /// the distance matrix is the canonical two-level SLIT (10 local, 21
    /// remote) — exactly the pre-refactor scalar model, so presets built
    /// through here keep bit-identical [`MachineTopology::capacities`]
    /// vectors.
    #[allow(clippy::too_many_arguments)]
    pub fn uniform(name: &str, sockets: usize, cores_per_socket: usize,
                   local_read_bw: f64, local_write_bw: f64,
                   qpi_read_bw: f64, qpi_write_bw: f64,
                   local_latency_ns: f64, remote_latency_ns: f64,
                   core_peak_bw: f64, price_usd: f64) -> MachineTopology {
        let s = sockets;
        let links = s * (s.saturating_sub(1));
        let mut latency = vec![remote_latency_ns; s * s];
        let mut distance = vec![2 * LOCAL_DISTANCE + 1; s * s];
        for i in 0..s {
            latency[i * s + i] = local_latency_ns;
            distance[i * s + i] = LOCAL_DISTANCE;
        }
        MachineTopology {
            name: name.to_string(),
            sockets,
            cores_per_socket,
            chan_read_bw: vec![local_read_bw; s],
            chan_write_bw: vec![local_write_bw; s],
            link_read_bw: vec![qpi_read_bw; links],
            link_write_bw: vec![qpi_write_bw; links],
            node_distance: distance,
            latency_matrix_ns: latency,
            core_peak_bw,
            price_usd,
            attrs: TopologyAttrs::default(),
        }
    }

    // ---- presets (calibrated to the paper's Fig 2 ratios) -----------------

    /// Dual-socket Xeon E5-2630 v3 (8 cores/socket, 2.4 GHz Haswell).
    /// Fig 2: remote read ≈ 0.16× local read, remote write ≈ 0.23× local
    /// write; strong local channels, narrow interconnect; $667/CPU.
    pub fn xeon_e5_2630_v3() -> MachineTopology {
        let local_read = 44.0 * GB;
        let local_write = 30.0 * GB;
        // 8 fast cores nearly saturate the local channel: the machine is
        // bandwidth-bound, hence placement-sensitive (Fig 1).
        Self::uniform("xeon-e5-2630v3-8c", 2, 8, local_read, local_write,
                      0.16 * local_read, 0.23 * local_write, 90.0, 200.0,
                      5.5 * GB, 667.0)
    }

    /// Dual-socket Xeon E5-2699 v3 (18 cores/socket, 2.3 GHz Haswell).
    /// Fig 2: remote read ≈ 0.59× local read, remote write ≈ 0.83× local
    /// write; comparable local channels, wide interconnect; $4115/CPU.
    pub fn xeon_e5_2699_v3() -> MachineTopology {
        let local_read = 50.0 * GB;
        let local_write = 34.0 * GB;
        // Streaming issue limit per core; what makes this machine
        // forgiving (Fig 1) is its wide QPI, not a core bottleneck.
        Self::uniform("xeon-e5-2699v3-18c", 2, 18, local_read, local_write,
                      0.59 * local_read, 0.83 * local_write, 95.0, 160.0,
                      10.0 * GB, 4115.0)
    }

    /// Synthetic quad-socket machine (no hardware counterpart in the
    /// paper): four sockets on a fully-connected interconnect with
    /// Fig-2-like capacity ratios.  Exercises the S-socket generalisation
    /// (§5.2 normalization, the generic flow layout, `fit_multi`) end to
    /// end — the topology class the multi-socket thread-migration
    /// literature targets (arXiv:1809.10937 evaluates on 4-socket NUMA
    /// hosts).
    pub fn synthetic_quad() -> MachineTopology {
        let local_read = 46.0 * GB;
        let local_write = 32.0 * GB;
        Self::uniform("synth-quad-4s", 4, 8, local_read, local_write,
                      0.40 * local_read, 0.55 * local_write, 95.0, 180.0,
                      6.0 * GB, 2500.0)
    }

    /// Both paper machines, in presentation order.
    pub fn paper_machines() -> Vec<MachineTopology> {
        vec![Self::xeon_e5_2630_v3(), Self::xeon_e5_2699_v3()]
    }

    /// Every built-in machine: the paper pair plus the synthetic
    /// quad-socket topology.
    pub fn builtin_machines() -> Vec<MachineTopology> {
        let mut ms = Self::paper_machines();
        ms.push(Self::synthetic_quad());
        ms
    }

    /// The preset names `by_name` accepts, short form first (rendered in
    /// unknown-machine errors).
    pub fn preset_names() -> &'static [(&'static str, &'static str)] {
        &[
            ("xeon8", "xeon-e5-2630v3-8c"),
            ("xeon18", "xeon-e5-2699v3-18c"),
            ("quad4", "synth-quad-4s"),
        ]
    }

    pub fn by_name(name: &str) -> Option<MachineTopology> {
        match name {
            "xeon8" | "xeon-e5-2630v3-8c" => Some(Self::xeon_e5_2630_v3()),
            "xeon18" | "xeon-e5-2699v3-18c" => Some(Self::xeon_e5_2699_v3()),
            "quad4" | "synth-quad-4s" => Some(Self::synthetic_quad()),
            _ => None,
        }
    }

    // ---- (de)serialization -------------------------------------------------

    /// The versioned topology-file JSON (see [`file`] for the format).
    /// Also what [`crate::coordinator::SignatureStore`] embeds so fitted
    /// stores are portable across hosts.
    pub fn to_json(&self) -> Json {
        file::to_json(self)
    }

    /// Parse (and [`MachineTopology::validate`]) the topology-file JSON.
    pub fn from_json(j: &Json) -> Result<MachineTopology, String> {
        file::from_json(j)
    }

    /// Semantic validation: shape of every per-resource vector and matrix,
    /// positivity of capacities and latencies, and the SLIT diagonal
    /// conventions.  Every boundary that accepts a non-preset topology
    /// (file load, sysfs discovery, the advisor) routes through here, so a
    /// hand-built topology with out-of-range shapes is a typed error — not
    /// release-mode silent nonsense from the index arithmetic.
    pub fn validate(&self) -> Result<(), String> {
        let s = self.sockets;
        let name = &self.name;
        if s < 2 {
            return Err(format!(
                "topology {name:?}: need >= 2 sockets (got {s}; a \
                 single-socket box has no interconnect to model)"
            ));
        }
        if self.cores_per_socket == 0 {
            return Err(format!(
                "topology {name:?}: need >= 1 core per socket"
            ));
        }
        let links = s * (s - 1);
        for (k, have, want) in [
            ("chan_read_bw", self.chan_read_bw.len(), s),
            ("chan_write_bw", self.chan_write_bw.len(), s),
            ("link_read_bw", self.link_read_bw.len(), links),
            ("link_write_bw", self.link_write_bw.len(), links),
            ("node_distance", self.node_distance.len(), s * s),
            ("latency_ns", self.latency_matrix_ns.len(), s * s),
        ] {
            if have != want {
                return Err(format!(
                    "topology {name:?}: {k} must have {want} entries for \
                     {s} sockets (got {have})"
                ));
            }
        }
        for (k, vs) in [
            ("chan_read_bw", &self.chan_read_bw),
            ("chan_write_bw", &self.chan_write_bw),
            ("link_read_bw", &self.link_read_bw),
            ("link_write_bw", &self.link_write_bw),
            ("latency_ns", &self.latency_matrix_ns),
        ] {
            if let Some(v) = vs.iter().find(|v| !(v.is_finite() && **v > 0.0))
            {
                return Err(format!(
                    "topology {name:?}: {k} entries must be positive \
                     (got {v})"
                ));
            }
        }
        if !(self.core_peak_bw.is_finite() && self.core_peak_bw > 0.0) {
            return Err(format!(
                "topology {name:?}: core_peak_bw must be positive"
            ));
        }
        if !(self.price_usd.is_finite() && self.price_usd >= 0.0) {
            return Err(format!(
                "topology {name:?}: price_usd must be non-negative"
            ));
        }
        for i in 0..s {
            let d_local = self.node_distance[i * s + i];
            if d_local == 0 {
                return Err(format!(
                    "topology {name:?}: node_distance diagonal entry \
                     [{i}][{i}] must be positive (SLIT local distance)"
                ));
            }
            let lat_local = self.latency_matrix_ns[i * s + i];
            for j in 0..s {
                if self.node_distance[i * s + j] < d_local {
                    return Err(format!(
                        "topology {name:?}: node_distance[{i}][{j}] is \
                         below the local distance [{i}][{i}] — the \
                         diagonal must be each row's minimum"
                    ));
                }
                if self.latency_matrix_ns[i * s + j] < lat_local {
                    return Err(format!(
                        "topology {name:?}: latency_ns[{i}][{j}] is below \
                         the local latency [{i}][{i}] — remote access \
                         cannot be faster than local"
                    ));
                }
            }
        }
        for (k, vs) in [("node_mem_mb", &self.attrs.node_mem_mb)] {
            if !vs.is_empty() && vs.len() != s {
                return Err(format!(
                    "topology {name:?}: attrs.{k} must have one entry per \
                     socket (expected {s}, got {})", vs.len()
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for m in MachineTopology::builtin_machines() {
            m.validate().unwrap();
        }
    }

    #[test]
    fn synthetic_quad_is_addressable_and_four_socket() {
        let q = MachineTopology::by_name("quad4").unwrap();
        assert_eq!(q, MachineTopology::synthetic_quad());
        assert_eq!(q.sockets, 4);
        assert_eq!(q.n_resources(), 32);
        assert_eq!(q.capacities().len(), 32);
    }

    #[test]
    fn paper_fig2_ratios() {
        let m8 = MachineTopology::xeon_e5_2630_v3();
        assert!((m8.link_read_cap(0, 1) / m8.chan_read_cap(0) - 0.16).abs()
                < 1e-9);
        assert!((m8.link_write_cap(0, 1) / m8.chan_write_cap(0) - 0.23)
                .abs() < 1e-9);
        let m18 = MachineTopology::xeon_e5_2699_v3();
        assert!((m18.link_read_cap(0, 1) / m18.chan_read_cap(0) - 0.59)
                .abs() < 1e-9);
        assert!((m18.link_write_cap(0, 1) / m18.chan_write_cap(0) - 0.83)
                .abs() < 1e-9);
        // The 18-core machine is the expensive one.
        assert!(m18.price_usd > m8.price_usd * 5.0);
    }

    #[test]
    fn preset_capacities_are_bit_identical_to_the_scalar_model() {
        // Pre-refactor oracle: the uniform scalar model repeated each
        // scalar once per resource.  The per-resource refactor must keep
        // every preset's capacity vector bit-for-bit.
        let cases: [(&str, f64, f64, f64, f64); 3] = [
            ("xeon8", 44.0 * GB, 30.0 * GB,
             0.16 * (44.0 * GB), 0.23 * (30.0 * GB)),
            ("xeon18", 50.0 * GB, 34.0 * GB,
             0.59 * (50.0 * GB), 0.83 * (34.0 * GB)),
            ("quad4", 46.0 * GB, 32.0 * GB,
             0.40 * (46.0 * GB), 0.55 * (32.0 * GB)),
        ];
        for (name, lr, lw, qr, qw) in cases {
            let m = MachineTopology::by_name(name).unwrap();
            let s = m.sockets;
            let mut want = Vec::new();
            want.extend(std::iter::repeat(lr).take(s));
            want.extend(std::iter::repeat(lw).take(s));
            want.extend(std::iter::repeat(qr).take(s * (s - 1)));
            want.extend(std::iter::repeat(qw).take(s * (s - 1)));
            let got = m.capacities();
            assert_eq!(got.len(), want.len(), "{name}");
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "{name} resource {i}");
            }
        }
    }

    #[test]
    fn resource_layout_matches_python_model_for_s2() {
        // DESIGN.md §6: [rc0, rc1, wc0, wc1, qr01, qr10, qw01, qw10].
        let m = MachineTopology::xeon_e5_2699_v3();
        assert_eq!(m.n_resources(), 8);
        assert_eq!(m.read_chan(0), 0);
        assert_eq!(m.read_chan(1), 1);
        assert_eq!(m.write_chan(0), 2);
        assert_eq!(m.write_chan(1), 3);
        assert_eq!(m.qpi_read_link(0, 1), 4);
        assert_eq!(m.qpi_read_link(1, 0), 5);
        assert_eq!(m.qpi_write_link(0, 1), 6);
        assert_eq!(m.qpi_write_link(1, 0), 7);
    }

    #[test]
    fn capacities_vector_matches_layout() {
        let m = MachineTopology::xeon_e5_2630_v3();
        let caps = m.capacities();
        assert_eq!(caps.len(), 8);
        assert_eq!(caps[m.read_chan(0)], m.chan_read_cap(0));
        assert_eq!(caps[m.write_chan(1)], m.chan_write_cap(1));
        assert_eq!(caps[m.qpi_read_link(1, 0)], m.link_read_cap(1, 0));
        assert_eq!(caps[m.qpi_write_link(0, 1)], m.link_write_cap(0, 1));
    }

    #[test]
    fn four_socket_layout_is_dense_and_disjoint() {
        let m = MachineTopology::uniform("dense4", 4, 8, 44.0 * GB,
                                         30.0 * GB, 7.0 * GB, 6.9 * GB,
                                         90.0, 200.0, 5.5 * GB, 0.0);
        m.validate().unwrap();
        assert_eq!(m.n_resources(), 2 * 4 + 2 * 12);
        let mut seen = std::collections::BTreeSet::new();
        for s in 0..4 {
            assert!(seen.insert(m.read_chan(s)));
            assert!(seen.insert(m.write_chan(s)));
        }
        for src in 0..4 {
            for dst in 0..4 {
                if src != dst {
                    assert!(seen.insert(m.qpi_read_link(src, dst)));
                    assert!(seen.insert(m.qpi_write_link(src, dst)));
                }
            }
        }
        assert_eq!(seen.len(), m.n_resources());
        assert_eq!(*seen.iter().max().unwrap(), m.n_resources() - 1);
    }

    #[test]
    fn json_roundtrip() {
        let m = MachineTopology::xeon_e5_2630_v3();
        let j = m.to_json();
        let back = MachineTopology::from_json(&j).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn from_json_rejects_invalid() {
        let mut j = MachineTopology::xeon_e5_2630_v3().to_json();
        j.set("sockets", Json::Num(1.0));
        assert!(MachineTopology::from_json(&j).is_err());
        let mut j2 = MachineTopology::xeon_e5_2630_v3().to_json();
        j2.set("core_peak_bw", Json::Num(-1.0));
        assert!(MachineTopology::from_json(&j2).is_err());
    }

    #[test]
    fn latency_lookup() {
        let m = MachineTopology::xeon_e5_2630_v3();
        assert_eq!(m.latency_ns(0, 0), 90.0);
        assert_eq!(m.latency_ns(0, 1), 200.0);
        assert_eq!(m.local_latency_ns(), 90.0);
        assert_eq!(m.distance(0, 0), LOCAL_DISTANCE);
        assert!(m.distance(0, 1) > LOCAL_DISTANCE);
    }

    #[test]
    fn asymmetric_latency_matrix_is_expressible() {
        // A matrix no local/remote scalar pair can express: each socket
        // sees different remote latencies, and the matrix need not be
        // symmetric across the diagonal.
        let mut m = MachineTopology::uniform("asym2", 2, 8, 44.0 * GB,
                                             30.0 * GB, 7.0 * GB, 6.9 * GB,
                                             90.0, 200.0, 5.5 * GB, 0.0);
        m.latency_matrix_ns = vec![90.0, 200.0, 140.0, 95.0];
        m.validate().unwrap();
        assert_eq!(m.latency_ns(0, 1), 200.0);
        assert_eq!(m.latency_ns(1, 0), 140.0);
        assert_eq!(m.local_latency_ns(), 90.0);
    }

    #[test]
    fn validate_catches_shape_and_diagonal_errors() {
        // Hand-built nonsense (sockets resized, vectors not): a validated
        // error naming the offending field, not silent index arithmetic.
        let mut m = MachineTopology::xeon_e5_2630_v3();
        m.sockets = 4;
        let err = m.validate().unwrap_err();
        assert!(err.contains("chan_read_bw"), "{err}");

        let mut m = MachineTopology::xeon_e5_2630_v3();
        m.link_read_bw[1] = -1.0;
        assert!(m.validate().unwrap_err().contains("link_read_bw"));

        let mut m = MachineTopology::xeon_e5_2630_v3();
        m.node_distance[1] = 3; // below the local distance 10
        assert!(m.validate().unwrap_err().contains("node_distance"));

        let mut m = MachineTopology::xeon_e5_2630_v3();
        m.latency_matrix_ns[1] = 10.0; // remote faster than local
        assert!(m.validate().unwrap_err().contains("latency_ns"));

        let mut m = MachineTopology::xeon_e5_2630_v3();
        m.attrs.node_mem_mb = vec![1024]; // one entry, two sockets
        assert!(m.validate().unwrap_err().contains("node_mem_mb"));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_socket_index_panics_in_release_too() {
        let m = MachineTopology::xeon_e5_2630_v3();
        m.read_chan(2);
    }
}
