//! Machine topology: the NUMA hardware description the simulator executes
//! on and the model predicts for (paper §2, Figs 2–3).
//!
//! A machine has `sockets` sockets, each with `cores_per_socket` cores and
//! a directly-attached memory bank reached over a memory channel; sockets
//! are joined by a point-to-point interconnect (QPI on the paper's Xeons).
//! Capacities are expressed in bytes/second; latencies in nanoseconds.
//!
//! Read and write interconnect capacities are modeled as separate
//! resources because the paper's Fig 2 measures them separately and finds
//! very different ratios (8-core: remote read 0.16× local vs remote write
//! 0.23×; 18-core: 0.59× vs 0.83×).

use crate::util::json::Json;

/// Gigabyte per second in bytes/second.
pub const GB: f64 = 1e9;

/// Resource footprint of performance-query flow `(src, dst, rw)` on an
/// S-socket machine (flow order `(src*S + dst)*2 + rw`, the S-socket
/// generalisation of `model.py build_incidence`'s 2-socket
/// `src*4 + dst*2 + rw`): the memory channel at the destination bank, plus
/// the interconnect link for remote flows — read data crosses the
/// `dst -> src` read link, write data the `src -> dst` write link.
/// Index arithmetic matches [`MachineTopology::read_chan`] /
/// [`MachineTopology::write_chan`] / [`MachineTopology::qpi_read_link`] /
/// [`MachineTopology::qpi_write_link`].  Single source of truth shared by
/// the reference `predict_performance`, the advisor's headroom accounting,
/// and the runtime's synthesized flow→resource incidence
/// ([`crate::runtime::Artifacts::synthesize`]).
pub fn flow_resources(sockets: usize, src: usize, dst: usize,
                      rw: usize) -> (usize, Option<usize>) {
    let s = sockets;
    // Dense index over ordered pairs (a, b), a != b (row-major, matching
    // MachineTopology::link_offset).
    let off = |a: usize, b: usize| {
        a * (s - 1) + if b > a { b - 1 } else { b }
    };
    let chan = if rw == 0 { dst } else { s + dst };
    let link = if src != dst {
        Some(if rw == 0 {
            2 * s + off(dst, src)
        } else {
            2 * s + s * (s - 1) + off(src, dst)
        })
    } else {
        None
    };
    (chan, link)
}

/// Description of one NUMA machine.
#[derive(Clone, Debug, PartialEq)]
pub struct MachineTopology {
    pub name: String,
    pub sockets: usize,
    pub cores_per_socket: usize,
    /// Local memory-channel read capacity per socket (bytes/s).
    pub local_read_bw: f64,
    /// Local memory-channel write capacity per socket (bytes/s).
    pub local_write_bw: f64,
    /// Interconnect read capacity per directed link (bytes/s): the rate at
    /// which read *data* can cross from one socket's bank to another's CPU.
    pub qpi_read_bw: f64,
    /// Interconnect write capacity per directed link (bytes/s).
    pub qpi_write_bw: f64,
    /// Load-to-use latency of the local bank (ns).
    pub local_latency_ns: f64,
    /// Load-to-use latency of a remote bank (ns).
    pub remote_latency_ns: f64,
    /// Peak memory demand a single core can generate against an idle local
    /// bank (bytes/s) — the CPU-side issue limit that makes the 18-core
    /// machine "CPU-bound and forgiving" in Fig 1.
    pub core_peak_bw: f64,
    /// Suggested retail price per CPU, USD (the paper's cost argument).
    pub price_usd: f64,
}

impl MachineTopology {
    pub fn total_cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }

    /// Number of contention resources: one read + one write channel per
    /// socket, plus read and write capacities for each directed
    /// interconnect link.
    pub fn n_resources(&self) -> usize {
        2 * self.sockets + 2 * self.sockets * (self.sockets - 1)
    }

    /// Resource index of socket `s`'s channel. Layout (matching the Python
    /// model for S=2): `[read_chan..., write_chan..., qpi_r links...,
    /// qpi_w links...]` with links ordered by `(src, dst), src != dst`,
    /// row-major.
    pub fn read_chan(&self, s: usize) -> usize {
        debug_assert!(s < self.sockets);
        s
    }

    pub fn write_chan(&self, s: usize) -> usize {
        debug_assert!(s < self.sockets);
        self.sockets + s
    }

    fn link_offset(&self, src: usize, dst: usize) -> usize {
        debug_assert!(src != dst);
        // Dense index over ordered pairs (src, dst), src != dst.
        src * (self.sockets - 1) + if dst > src { dst - 1 } else { dst }
    }

    pub fn qpi_read_link(&self, src: usize, dst: usize) -> usize {
        2 * self.sockets + self.link_offset(src, dst)
    }

    pub fn qpi_write_link(&self, src: usize, dst: usize) -> usize {
        2 * self.sockets
            + self.sockets * (self.sockets - 1)
            + self.link_offset(src, dst)
    }

    /// Capacity vector over all resources (order per the index functions).
    pub fn capacities(&self) -> Vec<f64> {
        let s = self.sockets;
        let mut caps = Vec::with_capacity(self.n_resources());
        caps.extend(std::iter::repeat(self.local_read_bw).take(s));
        caps.extend(std::iter::repeat(self.local_write_bw).take(s));
        caps.extend(std::iter::repeat(self.qpi_read_bw).take(s * (s - 1)));
        caps.extend(std::iter::repeat(self.qpi_write_bw).take(s * (s - 1)));
        caps
    }

    /// Latency seen by a thread on `src` accessing bank `dst`.
    pub fn latency_ns(&self, src: usize, dst: usize) -> f64 {
        if src == dst {
            self.local_latency_ns
        } else {
            self.remote_latency_ns
        }
    }

    // ---- presets (calibrated to the paper's Fig 2 ratios) -----------------

    /// Dual-socket Xeon E5-2630 v3 (8 cores/socket, 2.4 GHz Haswell).
    /// Fig 2: remote read ≈ 0.16× local read, remote write ≈ 0.23× local
    /// write; strong local channels, narrow interconnect; $667/CPU.
    pub fn xeon_e5_2630_v3() -> MachineTopology {
        let local_read = 44.0 * GB;
        let local_write = 30.0 * GB;
        MachineTopology {
            name: "xeon-e5-2630v3-8c".to_string(),
            sockets: 2,
            cores_per_socket: 8,
            local_read_bw: local_read,
            local_write_bw: local_write,
            qpi_read_bw: 0.16 * local_read,
            qpi_write_bw: 0.23 * local_write,
            local_latency_ns: 90.0,
            remote_latency_ns: 200.0,
            // 8 fast cores nearly saturate the local channel: the machine
            // is bandwidth-bound, hence placement-sensitive (Fig 1).
            core_peak_bw: 5.5 * GB,
            price_usd: 667.0,
        }
    }

    /// Dual-socket Xeon E5-2699 v3 (18 cores/socket, 2.3 GHz Haswell).
    /// Fig 2: remote read ≈ 0.59× local read, remote write ≈ 0.83× local
    /// write; comparable local channels, wide interconnect; $4115/CPU.
    pub fn xeon_e5_2699_v3() -> MachineTopology {
        let local_read = 50.0 * GB;
        let local_write = 34.0 * GB;
        MachineTopology {
            name: "xeon-e5-2699v3-18c".to_string(),
            sockets: 2,
            cores_per_socket: 18,
            local_read_bw: local_read,
            local_write_bw: local_write,
            qpi_read_bw: 0.59 * local_read,
            qpi_write_bw: 0.83 * local_write,
            local_latency_ns: 95.0,
            remote_latency_ns: 160.0,
            // Streaming issue limit per core; what makes this machine
            // forgiving (Fig 1) is its wide QPI, not a core bottleneck.
            core_peak_bw: 10.0 * GB,
            price_usd: 4115.0,
        }
    }

    /// Synthetic quad-socket machine (no hardware counterpart in the
    /// paper): four sockets on a fully-connected interconnect with
    /// Fig-2-like capacity ratios.  Exercises the S-socket generalisation
    /// (§5.2 normalization, the generic flow layout, `fit_multi`) end to
    /// end — the topology class the multi-socket thread-migration
    /// literature targets (arXiv:1809.10937 evaluates on 4-socket NUMA
    /// hosts).
    pub fn synthetic_quad() -> MachineTopology {
        let local_read = 46.0 * GB;
        let local_write = 32.0 * GB;
        MachineTopology {
            name: "synth-quad-4s".to_string(),
            sockets: 4,
            cores_per_socket: 8,
            local_read_bw: local_read,
            local_write_bw: local_write,
            qpi_read_bw: 0.40 * local_read,
            qpi_write_bw: 0.55 * local_write,
            local_latency_ns: 95.0,
            remote_latency_ns: 180.0,
            core_peak_bw: 6.0 * GB,
            price_usd: 2500.0,
        }
    }

    /// Both paper machines, in presentation order.
    pub fn paper_machines() -> Vec<MachineTopology> {
        vec![Self::xeon_e5_2630_v3(), Self::xeon_e5_2699_v3()]
    }

    /// Every built-in machine: the paper pair plus the synthetic
    /// quad-socket topology.
    pub fn builtin_machines() -> Vec<MachineTopology> {
        let mut ms = Self::paper_machines();
        ms.push(Self::synthetic_quad());
        ms
    }

    pub fn by_name(name: &str) -> Option<MachineTopology> {
        match name {
            "xeon8" | "xeon-e5-2630v3-8c" => Some(Self::xeon_e5_2630_v3()),
            "xeon18" | "xeon-e5-2699v3-18c" => Some(Self::xeon_e5_2699_v3()),
            "quad4" | "synth-quad-4s" => Some(Self::synthetic_quad()),
            _ => None,
        }
    }

    // ---- (de)serialization -------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::from_pairs([
            ("name", Json::Str(self.name.clone())),
            ("sockets", Json::Num(self.sockets as f64)),
            ("cores_per_socket", Json::Num(self.cores_per_socket as f64)),
            ("local_read_bw", Json::Num(self.local_read_bw)),
            ("local_write_bw", Json::Num(self.local_write_bw)),
            ("qpi_read_bw", Json::Num(self.qpi_read_bw)),
            ("qpi_write_bw", Json::Num(self.qpi_write_bw)),
            ("local_latency_ns", Json::Num(self.local_latency_ns)),
            ("remote_latency_ns", Json::Num(self.remote_latency_ns)),
            ("core_peak_bw", Json::Num(self.core_peak_bw)),
            ("price_usd", Json::Num(self.price_usd)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<MachineTopology, String> {
        let f = |k: &str| -> Result<f64, String> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("topology: missing numeric field {k}"))
        };
        let t = MachineTopology {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or("topology: missing name")?
                .to_string(),
            sockets: f("sockets")? as usize,
            cores_per_socket: f("cores_per_socket")? as usize,
            local_read_bw: f("local_read_bw")?,
            local_write_bw: f("local_write_bw")?,
            qpi_read_bw: f("qpi_read_bw")?,
            qpi_write_bw: f("qpi_write_bw")?,
            local_latency_ns: f("local_latency_ns")?,
            remote_latency_ns: f("remote_latency_ns")?,
            core_peak_bw: f("core_peak_bw")?,
            price_usd: f("price_usd")?,
        };
        t.validate()?;
        Ok(t)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.sockets < 2 {
            return Err("topology: need >= 2 sockets".into());
        }
        if self.cores_per_socket == 0 {
            return Err("topology: need >= 1 core per socket".into());
        }
        for (k, v) in [
            ("local_read_bw", self.local_read_bw),
            ("local_write_bw", self.local_write_bw),
            ("qpi_read_bw", self.qpi_read_bw),
            ("qpi_write_bw", self.qpi_write_bw),
            ("local_latency_ns", self.local_latency_ns),
            ("remote_latency_ns", self.remote_latency_ns),
            ("core_peak_bw", self.core_peak_bw),
        ] {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!("topology: {k} must be positive"));
            }
        }
        if self.remote_latency_ns < self.local_latency_ns {
            return Err("topology: remote latency below local".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for m in MachineTopology::builtin_machines() {
            m.validate().unwrap();
        }
    }

    #[test]
    fn synthetic_quad_is_addressable_and_four_socket() {
        let q = MachineTopology::by_name("quad4").unwrap();
        assert_eq!(q, MachineTopology::synthetic_quad());
        assert_eq!(q.sockets, 4);
        assert_eq!(q.n_resources(), 32);
        assert_eq!(q.capacities().len(), 32);
    }

    #[test]
    fn paper_fig2_ratios() {
        let m8 = MachineTopology::xeon_e5_2630_v3();
        assert!((m8.qpi_read_bw / m8.local_read_bw - 0.16).abs() < 1e-9);
        assert!((m8.qpi_write_bw / m8.local_write_bw - 0.23).abs() < 1e-9);
        let m18 = MachineTopology::xeon_e5_2699_v3();
        assert!((m18.qpi_read_bw / m18.local_read_bw - 0.59).abs() < 1e-9);
        assert!((m18.qpi_write_bw / m18.local_write_bw - 0.83).abs() < 1e-9);
        // The 18-core machine is the expensive one.
        assert!(m18.price_usd > m8.price_usd * 5.0);
    }

    #[test]
    fn resource_layout_matches_python_model_for_s2() {
        // DESIGN.md §6: [rc0, rc1, wc0, wc1, qr01, qr10, qw01, qw10].
        let m = MachineTopology::xeon_e5_2699_v3();
        assert_eq!(m.n_resources(), 8);
        assert_eq!(m.read_chan(0), 0);
        assert_eq!(m.read_chan(1), 1);
        assert_eq!(m.write_chan(0), 2);
        assert_eq!(m.write_chan(1), 3);
        assert_eq!(m.qpi_read_link(0, 1), 4);
        assert_eq!(m.qpi_read_link(1, 0), 5);
        assert_eq!(m.qpi_write_link(0, 1), 6);
        assert_eq!(m.qpi_write_link(1, 0), 7);
    }

    #[test]
    fn capacities_vector_matches_layout() {
        let m = MachineTopology::xeon_e5_2630_v3();
        let caps = m.capacities();
        assert_eq!(caps.len(), 8);
        assert_eq!(caps[m.read_chan(0)], m.local_read_bw);
        assert_eq!(caps[m.write_chan(1)], m.local_write_bw);
        assert_eq!(caps[m.qpi_read_link(1, 0)], m.qpi_read_bw);
        assert_eq!(caps[m.qpi_write_link(0, 1)], m.qpi_write_bw);
    }

    #[test]
    fn four_socket_layout_is_dense_and_disjoint() {
        let mut m = MachineTopology::xeon_e5_2699_v3();
        m.sockets = 4;
        assert_eq!(m.n_resources(), 2 * 4 + 2 * 12);
        let mut seen = std::collections::BTreeSet::new();
        for s in 0..4 {
            assert!(seen.insert(m.read_chan(s)));
            assert!(seen.insert(m.write_chan(s)));
        }
        for src in 0..4 {
            for dst in 0..4 {
                if src != dst {
                    assert!(seen.insert(m.qpi_read_link(src, dst)));
                    assert!(seen.insert(m.qpi_write_link(src, dst)));
                }
            }
        }
        assert_eq!(seen.len(), m.n_resources());
        assert_eq!(*seen.iter().max().unwrap(), m.n_resources() - 1);
    }

    #[test]
    fn json_roundtrip() {
        let m = MachineTopology::xeon_e5_2630_v3();
        let j = m.to_json();
        let back = MachineTopology::from_json(&j).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn from_json_rejects_invalid() {
        let mut j = MachineTopology::xeon_e5_2630_v3().to_json();
        j.set("sockets", Json::Num(1.0));
        assert!(MachineTopology::from_json(&j).is_err());
        let mut j2 = MachineTopology::xeon_e5_2630_v3().to_json();
        j2.set("core_peak_bw", Json::Num(-1.0));
        assert!(MachineTopology::from_json(&j2).is_err());
    }

    #[test]
    fn latency_lookup() {
        let m = MachineTopology::xeon_e5_2630_v3();
        assert_eq!(m.latency_ns(0, 0), 90.0);
        assert_eq!(m.latency_ns(0, 1), 200.0);
    }
}
