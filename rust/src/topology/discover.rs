//! Topology discovery from Linux sysfs.
//!
//! Builds a [`MachineTopology`] from the standard NUMA sysfs layout under
//! a root directory (normally `/sys`, mockable for tests and CI):
//!
//! * `devices/system/node/node<N>/distance` — the ACPI SLIT row for node
//!   N (whitespace-separated integers, local distance on the diagonal);
//! * `devices/system/node/node<N>/cpulist` — the node's CPUs as ranges
//!   (`0-7,16-23`); nodes with no CPUs (memory-only / CXL expanders) are
//!   excluded from the model, with the distance matrix subset to the
//!   remaining nodes;
//! * `devices/system/node/node<N>/meminfo` — `MemTotal` per node
//!   (recorded as inert `attrs.node_mem_mb` metadata when present);
//! * `devices/system/cpu/cpu0/cache/index*/size` and
//!   `node<N>/hugepages/hugepages-<K>kB/` — cache hierarchy and page
//!   sizes, recorded as inert metadata when present.
//!
//! sysfs carries no bandwidth or latency numbers, so those are **seeded**
//! from the distance matrix and the caller-overridable
//! [`DiscoverOptions`] scales: latency grows with distance
//! (`lat[i][j] = local_latency * d[i][j] / d[i][i]`) and link capacity
//! shrinks with it (`link[i][j] = local_bw * d[i][i] / d[i][j]`).  The
//! defaults are deliberately round numbers whose products with common
//! SLIT distances (10, 12, 21) stay exact integers, so discovered
//! topology files are byte-stable across hosts and toolchains.  For a
//! calibrated model, fit the discovered topology against real counter
//! runs (`numabw fit --machine @discovered.json`).

use std::path::{Path, PathBuf};

use crate::topology::{MachineTopology, TopologyAttrs, GB};

/// Caller-overridable scales for the bandwidth/latency fields sysfs does
/// not report.  Defaults (42 GB/s read, 33.6 GB/s write, 90 ns, 6 GB/s
/// core peak) are Haswell-class and chosen so distance-ratio seeding with
/// SLIT values 10/12/21 lands on exact integers.
#[derive(Clone, Debug)]
pub struct DiscoverOptions {
    /// Topology name; default `sysfs-<S>s<C>c`.
    pub name: Option<String>,
    /// Local memory-channel read capacity per socket (bytes/s).
    pub local_read_bw: f64,
    /// Local memory-channel write capacity per socket (bytes/s).
    pub local_write_bw: f64,
    /// Local load-to-use latency (ns).
    pub local_latency_ns: f64,
    /// Per-core peak demand (bytes/s).
    pub core_peak_bw: f64,
    /// Price metadata (USD); unknown by default.
    pub price_usd: f64,
}

impl Default for DiscoverOptions {
    fn default() -> Self {
        DiscoverOptions {
            name: None,
            local_read_bw: 42.0 * GB,
            local_write_bw: 33.6 * GB,
            local_latency_ns: 90.0,
            core_peak_bw: 6.0 * GB,
            price_usd: 0.0,
        }
    }
}

fn read_trim(path: &Path) -> Result<String, String> {
    std::fs::read_to_string(path)
        .map(|s| s.trim().to_string())
        .map_err(|e| format!("sysfs discover: {}: {e}", path.display()))
}

/// Number of CPUs in a sysfs `cpulist` string (`0-7,16-23`); an empty
/// list (memory-only node) is 0.
fn cpulist_count(list: &str) -> Result<usize, String> {
    let list = list.trim();
    if list.is_empty() {
        return Ok(0);
    }
    let mut count = 0usize;
    for tok in list.split(',') {
        let tok = tok.trim();
        let bad = || format!("sysfs discover: bad cpulist token {tok:?}");
        match tok.split_once('-') {
            Some((lo, hi)) => {
                let lo: usize = lo.trim().parse().map_err(|_| bad())?;
                let hi: usize = hi.trim().parse().map_err(|_| bad())?;
                if hi < lo {
                    return Err(bad());
                }
                count += hi - lo + 1;
            }
            None => {
                let _: usize = tok.parse().map_err(|_| bad())?;
                count += 1;
            }
        }
    }
    Ok(count)
}

/// `MemTotal` in MB from a node `meminfo` ("Node 0 MemTotal: ... kB").
fn meminfo_mb(text: &str) -> Option<u64> {
    for line in text.lines() {
        if let Some(rest) = line.split("MemTotal:").nth(1) {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim()
                .parse().ok()?;
            return Some(kb / 1024);
        }
    }
    None
}

/// Cache size in KB from a sysfs `size` string ("32K", "25344K", "30M").
fn cache_size_kb(text: &str) -> Option<u64> {
    let t = text.trim();
    if let Some(v) = t.strip_suffix('K') {
        v.parse().ok()
    } else if let Some(v) = t.strip_suffix('M') {
        v.parse::<u64>().ok().map(|m| m * 1024)
    } else if let Some(v) = t.strip_suffix('G') {
        v.parse::<u64>().ok().map(|g| g * 1024 * 1024)
    } else {
        None
    }
}

struct RawNode {
    id: usize,
    dir: PathBuf,
    cpus: usize,
    distance: Vec<u32>,
    mem_mb: Option<u64>,
}

/// Cache hierarchy of cpu0 (innermost first), empty if the cache
/// directory is absent (containers often hide it).
fn cache_hierarchy_kb(root: &Path) -> Vec<u64> {
    let cache_dir = root.join("devices/system/cpu/cpu0/cache");
    let mut levels: Vec<(usize, u64)> = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&cache_dir) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(n) = name.strip_prefix("index") {
                if let Ok(idx) = n.parse::<usize>() {
                    if let Ok(sz) = read_trim(&entry.path().join("size")) {
                        if let Some(kb) = cache_size_kb(&sz) {
                            levels.push((idx, kb));
                        }
                    }
                }
            }
        }
    }
    levels.sort();
    levels.into_iter().map(|(_, kb)| kb).collect()
}

/// Page sizes in KB: the 4 KB base page plus any hugepage pools the node
/// advertises.
fn page_sizes_kb(node_dir: &Path) -> Vec<u64> {
    let mut sizes = vec![4u64];
    if let Ok(entries) = std::fs::read_dir(node_dir.join("hugepages")) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(kb) = name.strip_prefix("hugepages-")
                .and_then(|n| n.strip_suffix("kB"))
                .and_then(|n| n.parse::<u64>().ok())
            {
                sizes.push(kb);
            }
        }
    }
    sizes.sort_unstable();
    sizes.dedup();
    sizes
}

/// Discover a topology from the sysfs tree rooted at `root` (normally
/// `/sys`; any directory with the same layout works, which is how tests
/// and CI exercise this without real hardware).
pub fn discover_from(root: &Path, opts: &DiscoverOptions)
    -> Result<MachineTopology, String>
{
    let node_root = root.join("devices/system/node");
    let entries = std::fs::read_dir(&node_root).map_err(|e| {
        format!("sysfs discover: {}: {e}", node_root.display())
    })?;
    let mut nodes: Vec<RawNode> = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let id = match name.strip_prefix("node")
            .and_then(|n| n.parse::<usize>().ok())
        {
            Some(id) => id,
            None => continue,
        };
        let dir = entry.path();
        let distance = read_trim(&dir.join("distance"))?
            .split_whitespace()
            .map(|t| t.parse::<u32>().map_err(|_| {
                format!("sysfs discover: {}: bad distance entry {t:?}",
                        dir.join("distance").display())
            }))
            .collect::<Result<Vec<u32>, String>>()?;
        let cpus = cpulist_count(&read_trim(&dir.join("cpulist"))?)?;
        let mem_mb = std::fs::read_to_string(dir.join("meminfo")).ok()
            .and_then(|t| meminfo_mb(&t));
        nodes.push(RawNode { id, dir, cpus, distance, mem_mb });
    }
    if nodes.is_empty() {
        return Err(format!(
            "sysfs discover: no node* directories under {}",
            node_root.display()
        ));
    }
    nodes.sort_by_key(|n| n.id);
    let total = nodes.len();
    for n in &nodes {
        if n.distance.len() != total {
            return Err(format!(
                "sysfs discover: node{} distance row has {} entries for \
                 {total} nodes", n.id, n.distance.len()
            ));
        }
    }

    // Model only nodes with CPUs; memory-only nodes (CXL expanders,
    // ballooned VMs) have no cores to place threads on.
    let kept: Vec<usize> = (0..total).filter(|&i| nodes[i].cpus > 0)
        .collect();
    if kept.len() < 2 {
        return Err(format!(
            "sysfs discover: found {} NUMA node(s) with CPUs under {} — \
             need >= 2 to model an interconnect (single-node boxes have \
             nothing to place)", kept.len(), node_root.display()
        ));
    }
    let s = kept.len();
    let cores_per_socket =
        kept.iter().map(|&i| nodes[i].cpus).min().unwrap();

    // Subset the distance matrix to the kept nodes and sanity-check the
    // SLIT conventions before seeding anything from the ratios.
    let mut distance = Vec::with_capacity(s * s);
    for &i in &kept {
        for &j in &kept {
            distance.push(nodes[i].distance[nodes[j].id]);
        }
    }
    for (row, &i) in kept.iter().enumerate() {
        let d_local = distance[row * s + row];
        if d_local == 0 {
            return Err(format!(
                "sysfs discover: node{} reports local distance 0 — \
                 cannot seed bandwidth from distance ratios", nodes[i].id
            ));
        }
        for (col, &j) in kept.iter().enumerate() {
            if distance[row * s + col] < d_local {
                return Err(format!(
                    "sysfs discover: node{} -> node{} distance {} is \
                     below the local distance {d_local} — malformed SLIT",
                    nodes[i].id, nodes[j].id, distance[row * s + col]
                ));
            }
        }
    }

    // Seed latency and per-link bandwidth from the distance ratios
    // (multiply before dividing so common SLIT ratios stay exact).
    let mut latency = Vec::with_capacity(s * s);
    let mut link_read = Vec::with_capacity(s * (s - 1));
    let mut link_write = Vec::with_capacity(s * (s - 1));
    for row in 0..s {
        let d_local = distance[row * s + row] as f64;
        for col in 0..s {
            let d = distance[row * s + col] as f64;
            latency.push(opts.local_latency_ns * d / d_local);
            if col != row {
                link_read.push(opts.local_read_bw * d_local / d);
                link_write.push(opts.local_write_bw * d_local / d);
            }
        }
    }

    let node_mem_mb: Vec<u64> = {
        let mems: Vec<Option<u64>> =
            kept.iter().map(|&i| nodes[i].mem_mb).collect();
        if mems.iter().all(Option::is_some) {
            mems.into_iter().flatten().collect()
        } else {
            Vec::new()
        }
    };
    let attrs = TopologyAttrs {
        node_mem_mb,
        cache_kb: cache_hierarchy_kb(root),
        page_kb: page_sizes_kb(&nodes[kept[0]].dir),
    };

    let name = opts.name.clone()
        .unwrap_or_else(|| format!("sysfs-{s}s{cores_per_socket}c"));
    let t = MachineTopology {
        name,
        sockets: s,
        cores_per_socket,
        chan_read_bw: vec![opts.local_read_bw; s],
        chan_write_bw: vec![opts.local_write_bw; s],
        link_read_bw: link_read,
        link_write_bw: link_write,
        node_distance: distance,
        latency_matrix_ns: latency,
        core_peak_bw: opts.core_peak_bw,
        price_usd: opts.price_usd,
        attrs,
    };
    t.validate()?;
    Ok(t)
}

/// Discover the host's topology from the real `/sys`.
pub fn discover(opts: &DiscoverOptions) -> Result<MachineTopology, String> {
    discover_from(Path::new("/sys"), opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    /// Build a throwaway sysfs-shaped tree; removed on drop.
    struct MockSysfs {
        root: PathBuf,
    }

    impl MockSysfs {
        fn new(tag: &str) -> MockSysfs {
            let root = std::env::temp_dir().join(format!(
                "numabw_discover_{}_{tag}", std::process::id()
            ));
            let _ = fs::remove_dir_all(&root);
            fs::create_dir_all(root.join("devices/system/node")).unwrap();
            MockSysfs { root }
        }

        fn node(&self, id: usize, distance: &str, cpulist: &str,
                meminfo: Option<&str>) {
            let dir = self.root
                .join(format!("devices/system/node/node{id}"));
            fs::create_dir_all(&dir).unwrap();
            fs::write(dir.join("distance"), format!("{distance}\n"))
                .unwrap();
            fs::write(dir.join("cpulist"), format!("{cpulist}\n"))
                .unwrap();
            if let Some(m) = meminfo {
                fs::write(dir.join("meminfo"), format!("{m}\n")).unwrap();
            }
        }
    }

    impl Drop for MockSysfs {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.root);
        }
    }

    #[test]
    fn parses_cpulists() {
        assert_eq!(cpulist_count("0-7,16-23").unwrap(), 16);
        assert_eq!(cpulist_count("0").unwrap(), 1);
        assert_eq!(cpulist_count("").unwrap(), 0);
        assert_eq!(cpulist_count("3,5,9-10").unwrap(), 4);
        assert!(cpulist_count("7-3").is_err());
        assert!(cpulist_count("x").is_err());
    }

    #[test]
    fn parses_meminfo_and_cache_sizes() {
        assert_eq!(
            meminfo_mb("Node 0 MemTotal:       33554432 kB\nNode 0 \
                        MemFree: 1 kB"),
            Some(32768)
        );
        assert_eq!(cache_size_kb("32K"), Some(32));
        assert_eq!(cache_size_kb("30M"), Some(30720));
        assert_eq!(cache_size_kb("x"), None);
    }

    #[test]
    fn two_node_tree_discovers_with_distance_seeding() {
        let mock = MockSysfs::new("two_node");
        mock.node(0, "10 21", "0-7",
                  Some("Node 0 MemTotal: 16777216 kB"));
        mock.node(1, "21 10", "8-15",
                  Some("Node 1 MemTotal: 16777216 kB"));
        let t = discover_from(&mock.root,
                              &DiscoverOptions::default()).unwrap();
        assert_eq!(t.name, "sysfs-2s8c");
        assert_eq!(t.sockets, 2);
        assert_eq!(t.cores_per_socket, 8);
        assert_eq!(t.latency_ns(0, 0), 90.0);
        assert_eq!(t.latency_ns(0, 1), 90.0 * 21.0 / 10.0);
        assert_eq!(t.link_read_cap(0, 1), 42.0 * GB * 10.0 / 21.0);
        assert_eq!(t.chan_read_cap(1), 42.0 * GB);
        assert_eq!(t.attrs.node_mem_mb, vec![16384, 16384]);
        assert_eq!(t.attrs.page_kb, vec![4]); // no hugepage dirs
        assert!(t.attrs.cache_kb.is_empty()); // no cpu0 cache dir
    }

    #[test]
    fn memory_only_nodes_are_excluded_and_matrix_subset() {
        let mock = MockSysfs::new("cxl");
        // node1 is a memory-only expander; the kept matrix must subset
        // both its row and its column.
        mock.node(0, "10 17 21", "0-7", None);
        mock.node(1, "17 10 28", "", None);
        mock.node(2, "21 28 10", "8-15", None);
        let t = discover_from(&mock.root,
                              &DiscoverOptions::default()).unwrap();
        assert_eq!(t.sockets, 2);
        assert_eq!(t.distance(0, 1), 21);
        assert_eq!(t.distance(1, 0), 21);
        assert!(t.attrs.node_mem_mb.is_empty()); // not all nodes report
    }

    #[test]
    fn single_cpu_node_is_an_error() {
        let mock = MockSysfs::new("single");
        mock.node(0, "10", "0-7", None);
        let err = discover_from(&mock.root, &DiscoverOptions::default())
            .unwrap_err();
        assert!(err.contains("1 NUMA node(s) with CPUs"), "{err}");
    }

    #[test]
    fn malformed_slit_is_an_error() {
        let mock = MockSysfs::new("badslit");
        mock.node(0, "10 8", "0-7", None);
        mock.node(1, "8 10", "8-15", None);
        let err = discover_from(&mock.root, &DiscoverOptions::default())
            .unwrap_err();
        assert!(err.contains("below the local distance"), "{err}");

        let mock = MockSysfs::new("shortrow");
        mock.node(0, "10", "0-7", None);
        mock.node(1, "21 10", "8-15", None);
        let err = discover_from(&mock.root, &DiscoverOptions::default())
            .unwrap_err();
        assert!(err.contains("distance row has 1 entries"), "{err}");
    }

    #[test]
    fn discovered_topology_roundtrips_through_the_file_format() {
        let mock = MockSysfs::new("roundtrip");
        mock.node(0, "10 12 21 21", "0-7", None);
        mock.node(1, "12 10 21 21", "8-15", None);
        mock.node(2, "21 21 10 12", "16-23", None);
        mock.node(3, "21 21 12 10", "24-31", None);
        let t = discover_from(&mock.root,
                              &DiscoverOptions::default()).unwrap();
        assert_eq!(t.sockets, 4);
        // Paired sockets (sub-NUMA-cluster shape): near links are wider
        // than far links — asymmetry the uniform model cannot express.
        assert!(t.link_read_cap(0, 1) > t.link_read_cap(0, 2));
        assert_eq!(t.link_read_cap(0, 1), 35.0 * GB);
        assert_eq!(t.link_read_cap(0, 2), 20.0 * GB);
        let text = crate::topology::file::to_json(&t).encode();
        let back = crate::topology::file::from_json(
            &crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, t);
        assert_eq!(crate::topology::file::to_json(&back).encode(), text);
    }
}
