//! `numabw` binary entrypoint — see [`numabw::cli`] for the subcommands.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = numabw::cli::main_with(args) {
        eprintln!("numabw: {e:#}");
        std::process::exit(1);
    }
}
