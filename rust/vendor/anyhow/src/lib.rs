//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build has no crates.io access (DESIGN: every substrate is vendored
//! in-repo — see `numabw::util` for the PRNG/JSON/args/stats equivalents),
//! so this crate implements exactly the surface `numabw` uses:
//!
//! * [`Error`] — a context chain of messages.  Like the real `anyhow`,
//!   `Error` deliberately does **not** implement `std::error::Error`, so
//!   the blanket `From<E: std::error::Error>` conversion below stays
//!   coherent.
//! * [`Result<T>`] with the `Error` default.
//! * [`anyhow!`] / [`bail!`] macros.
//! * The [`Context`] extension trait (`.context(..)` / `.with_context(..)`).
//!
//! Display follows anyhow's convention: `{}` prints the outermost message,
//! `{:#}` prints the whole chain joined with `": "`.

use std::fmt;

/// An error: a chain of human-readable messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts, capturing its source chain.  (`Error` itself is
/// not a `std::error::Error`, so this does not overlap the reflexive
/// `From<Error> for Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Attach context to a `Result`'s error.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error =
            Result::<(), _>::Err(io_err()).context("reading x").unwrap_err();
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: missing");
    }

    #[test]
    fn macros_build_messages() {
        let name = "cg";
        let e = anyhow!("unknown workload {name}");
        assert_eq!(format!("{e}"), "unknown workload cg");
        let e2 = anyhow!("{} + {}", 1, 2);
        assert_eq!(format!("{e2}"), "1 + 2");
        let e3 = anyhow!(String::from("plain"));
        assert_eq!(format!("{e3}"), "plain");
    }

    #[test]
    fn bail_returns_err() {
        fn f() -> Result<()> {
            bail!("nope: {}", 7);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope: 7");
    }

    #[test]
    fn with_context_lazily_formats() {
        let r: Result<(), _> = std::result::Result::Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
