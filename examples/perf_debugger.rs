//! Performance debugger — the paper's development-time use case: model an
//! application's bandwidth requirements against *hardware descriptions it
//! has never run on* and flag problematic memory-access patterns before
//! the application reaches that environment.
//!
//!     cargo run --release --example perf_debugger [--workload npo]
//!
//! Checks performed per target machine:
//!   * static-bank saturation: a large Static fraction funnels every
//!     thread into one memory channel;
//!   * interconnect saturation: remote traffic vs QPI capacity at full
//!     thread count;
//!   * model misfit (§6.2.1): placement-dependent behaviour the signature
//!     cannot express — predictions should be treated as approximate.

use numabw::coordinator::{profile, FitRequest, PerfQuery,
                          PredictionService};
use numabw::model::misfit::{self, FitQuality};
use numabw::prelude::*;
use numabw::report;
use numabw::util::args::Args;
use numabw::workloads::suite;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let workload = suite::by_name(args.get_or("workload", "npo"))
        .expect("workload name from Table 1");
    let svc = PredictionService::auto();

    // Profile on the dev box (the 18-core machine), then reason about any
    // target hardware from the signature alone.
    let dev = MachineTopology::xeon_e5_2699_v3();
    let sim = Simulator::new(dev.clone(), SimConfig::default());
    let pair = profile(&sim, &workload);
    let sig = &svc.fit(&[FitRequest { sym: pair.sym, asym: pair.asym }])?[0];

    println!("perf-debug report for `{}` (profiled on {})\n", workload.name,
             dev.name);
    let s = &sig.combined;
    println!("signature: {} static={:.2}@{} local={:.2} perthread={:.2} \
              interleave={:.2}\n",
             report::signature_bar(s.static_frac, s.local_frac,
                                   s.perthread_frac, s.interleave_frac(),
                                   32),
             s.static_frac, s.static_socket, s.local_frac, s.perthread_frac,
             s.interleave_frac());

    // A hypothetical future target: narrow interconnect, many cores.
    let mut narrow = MachineTopology::xeon_e5_2630_v3();
    narrow.name = "target-narrow-qpi".into();
    narrow.cores_per_socket = 16;

    let mut warnings = 0;
    for machine in [dev.clone(), MachineTopology::xeon_e5_2630_v3(), narrow]
    {
        println!("--- target: {} ---", machine.name);
        let full = machine.cores_per_socket;
        let threads = vec![full; machine.sockets];
        let sockets = machine.sockets as f64;
        let per_thread = workload.bw_per_thread.min(machine.core_peak_bw);
        let demand_total = per_thread * (machine.sockets * full) as f64;

        // Where does the traffic land under an even spread?  Each socket
        // issues 1/S of the demand; sum every socket's share routed to
        // the static bank (reduces to the 2-socket arithmetic for S=2).
        let m = s.apply(&threads);
        let static_bank_load: f64 = demand_total / sockets
            * (0..machine.sockets)
                .map(|src| m[src][s.static_socket])
                .sum::<f64>();
        let chan_cap = machine.local_read_bw;
        if static_bank_load > 0.8 * chan_cap {
            println!("  WARN: bank {} would carry {} of {} channel \
                      capacity — static allocation is a bottleneck \
                      (consider interleaving the shared input)",
                     s.static_socket, report::fmt_bw(static_bank_load),
                     report::fmt_bw(chan_cap));
            warnings += 1;
        }
        // Remote traffic vs interconnect: mean off-diagonal mass per
        // source socket, spread over the S(S-1) directed links.
        let remote_frac = (0..machine.sockets)
            .map(|src| {
                (0..machine.sockets)
                    .filter(|&dst| dst != src)
                    .map(|dst| m[src][dst])
                    .sum::<f64>()
            })
            .sum::<f64>()
            / sockets;
        let remote_load =
            demand_total * remote_frac / (sockets * (sockets - 1.0));
        if remote_load > 0.8 * machine.qpi_read_bw {
            println!("  WARN: ~{} of remote traffic per QPI direction vs \
                      {} capacity — expect interconnect saturation",
                     report::fmt_bw(remote_load),
                     report::fmt_bw(machine.qpi_read_bw));
            warnings += 1;
        }
        // Predicted achieved bandwidth at full blast.
        let q = PerfQuery {
            sig: *s,
            threads: threads.clone(),
            demand_pt: [per_thread * workload.read_fraction,
                        per_thread * (1.0 - workload.read_fraction)],
            caps: machine.capacities(),
        };
        let achieved: f64 = svc.predict_performance(&[q])?[0].iter().sum();
        println!("  predicted achieved: {} of {} demanded ({:.0}%)",
                 report::fmt_bw(achieved), report::fmt_bw(demand_total),
                 100.0 * achieved / demand_total);
    }

    if misfit::assess(sig) != FitQuality::Good {
        println!("\n{}", misfit::describe(sig));
        warnings += 1;
    }
    println!("\n{warnings} warning(s). Fix these before the testing stage \
              — that is the point of modeling (paper §1).");
    Ok(())
}
