//! End-to-end reproduction driver — the full paper pipeline on a real
//! (simulated-testbed) workload, proving all three layers compose:
//!
//!   Rust simulator substrate (counters)
//!     → §5.1 profiling orchestration (Rust coordinator)
//!     → §5 signature fit (HLO-text modules through the interpreter
//!       engine — AOT artifacts when present, emitted offline otherwise)
//!     → §4/§6.2.2 predictions for every thread split (same path)
//!     → error statistics vs the paper's published numbers.
//!
//!     cargo run --release --example e2e_reproduction
//!
//! Results are recorded in EXPERIMENTS.md.  Writes `e2e_results.json`.

use std::time::Instant;

use numabw::coordinator::{evaluate_suite, PredictionService};
use numabw::eval;
use numabw::prelude::*;
use numabw::report;
use numabw::runtime::Engine;
use numabw::util::json::Json;
use numabw::util::stats::Cdf;
use numabw::workloads::suite;

fn main() -> anyhow::Result<()> {
    println!("=== numabw end-to-end reproduction ===\n");

    // Layer check: the HLO modules must parse and execute — this run is
    // about proving the full stack, so no silent reference fallback
    // (from_env loads AOT artifacts when present, emitted modules
    // otherwise; a broken artifacts dir is an error).
    let engine = Engine::from_env()?;
    engine.warmup()?;
    println!("hlo engine up: {} pipelines loaded (batch {})",
             numabw::runtime::PIPELINES.len(), engine.batch());
    let svc = PredictionService::hlo(engine);

    let ws = suite::table1();
    let t0 = Instant::now();
    let mut evs = Vec::new();
    for machine in MachineTopology::paper_machines() {
        let sim = Simulator::new(machine.clone(), SimConfig::default());
        let t = Instant::now();
        let ev = evaluate_suite(&sim, &svc, &ws, None)?;
        println!("{}: {} workloads, {} points in {:.2}s", ev.machine,
                 ws.len(), ev.records.len(), t.elapsed().as_secs_f64());
        evs.push(ev);
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---- headline numbers (Fig 17) -----------------------------------------
    let (median, at25, at10) =
        eval::headline(&evs.iter().collect::<Vec<_>>());
    println!("\n== headline vs paper ==");
    println!("median error:    {median:.2}%   (paper: 2.34%)");
    println!("within 2.5%:     {:.0}%     (paper: >50%)", at25 * 100.0);
    println!("within 10%:      {:.0}%     (paper: 75%)", at10 * 100.0);

    // ---- stability (Figs 14/15) --------------------------------------------
    let rows = eval::stability(&evs[0], &evs[1], 2);
    let cdf = eval::stability_cdf(&rows);
    let changes: Vec<f64> =
        rows.iter().map(|r| r.combined_change_pct).collect();
    let mean_change = changes.iter().sum::<f64>() / changes.len() as f64;
    println!("\n== signature stability vs paper ==");
    println!("combined change: mean {:.1}% median {:.1}% (paper: 6.8% / \
              4.2%)", mean_change, cdf.median());

    // ---- misfit detection (Fig 16) ----------------------------------------
    let pr = evs[1].signature("pagerank").unwrap();
    let pr_err = Cdf::of(&evs[1].errors_for("pagerank"));
    println!("\n== pagerank misfit (Fig 16) ==");
    println!("misfit residual {:.3} (conforming benchmarks: <0.03); \
              median error {:.1}%", pr.read.misfit, pr_err.median());

    // ---- Fig 18 correlation -------------------------------------------------
    let acc = eval::accuracy_by_benchmark(&evs[1]);
    let mut low_bw: Vec<&eval::AccuracyRow> = acc
        .iter()
        .filter(|r| r.avg_bandwidth < 2.0 * GB)
        .collect();
    low_bw.sort_by(|a, b| a.avg_bandwidth.partial_cmp(&b.avg_bandwidth)
        .unwrap());
    println!("\n== low-bandwidth benchmarks carry the errors (Fig 18) ==");
    for r in low_bw {
        println!("  {:10} {:>12}  avg err {:.2}%", r.workload,
                 report::fmt_bw(r.avg_bandwidth), r.avg_err_pct);
    }

    // ---- persist --------------------------------------------------------------
    let mut out = Json::obj();
    out.set("median_err_pct", Json::Num(median));
    out.set("frac_within_2_5", Json::Num(at25));
    out.set("frac_within_10", Json::Num(at10));
    out.set("stability_median_pct", Json::Num(cdf.median()));
    out.set("stability_mean_pct", Json::Num(mean_change));
    out.set("pagerank_misfit", Json::Num(pr.read.misfit));
    out.set("total_points",
            Json::Num(evs.iter().map(|e| e.records.len()).sum::<usize>()
                as f64));
    out.set("wall_seconds", Json::Num(wall));
    std::fs::write("e2e_results.json", out.encode())?;
    println!("\nwrote e2e_results.json; total {} points in {wall:.1}s \
              (HLO request path, Python not involved)",
             evs.iter().map(|e| e.records.len()).sum::<usize>());
    Ok(())
}
