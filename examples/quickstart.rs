//! Quickstart: profile a workload with two runs, fit its bandwidth
//! signature, and predict the traffic of an unseen placement.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the Rust reference model by default; pass
//! `--engine native|hlo` to route the fit and predictions through a
//! batched execution backend (both run everywhere: native is the
//! in-process f32 engine, hlo interprets emitted — or AOT-exported —
//! HLO-text modules).

use numabw::coordinator::{profile, FitRequest, PredictionService};
use numabw::model::misfit;
use numabw::prelude::*;
use numabw::report;
use numabw::workloads::suite;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let engine = args
        .iter()
        .position(|a| a == "--engine")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("reference");
    let svc = PredictionService::by_name(engine)?;
    println!("engine:   {}", svc.backend_name());

    // The 18-core Haswell testbed from the paper, and the CG benchmark.
    let machine = MachineTopology::xeon_e5_2699_v3();
    let sim = Simulator::new(machine.clone(), SimConfig::default());
    let workload = suite::by_name("cg").expect("cg is in Table 1");

    println!("machine:  {} ({}x{} cores)", machine.name, machine.sockets,
             machine.cores_per_socket);
    println!("workload: {} — {}\n", workload.name, workload.description);

    // 1. Two profiling runs (§5.1): symmetric + asymmetric.
    let pair = profile(&sim, &workload);
    println!("profiled: symmetric {:?} + asymmetric {:?}",
             pair.sym.threads_per_socket, pair.asym.threads_per_socket);

    // 2. Fit the bandwidth signature (§5).
    let sig = &svc.fit(&[FitRequest { sym: pair.sym, asym: pair.asym }])?[0];
    for (ch, s) in [("read", &sig.read), ("write", &sig.write)] {
        println!(
            "{ch:>6}: {} static={:.2}@{} local={:.2} perthread={:.2} \
             interleave={:.2}",
            report::signature_bar(s.static_frac, s.local_frac,
                                  s.perthread_frac, s.interleave_frac(), 28),
            s.static_frac, s.static_socket, s.local_frac, s.perthread_frac,
            s.interleave_frac()
        );
    }
    println!("{}\n", misfit::describe(sig));

    // 3. Apply the signature to a placement we never measured (§4).
    let placement = [14usize, 4usize];
    let m = sig.read.apply(&placement);
    println!("predicted read-traffic fractions for threads {placement:?}:");
    for (src, row) in m.iter().enumerate() {
        println!("  cpu{src} -> bank0 {:.3}, bank1 {:.3}", row[0], row[1]);
    }

    // 4. Sanity-check against a real (simulated) run of that placement.
    let measured = sim.run(&workload,
                           &ThreadPlacement::new(placement.to_vec()));
    println!("\nmeasured bandwidth at {placement:?}: {}",
             report::fmt_bw(measured.achieved_bw));
    println!("\nnext: `cargo bench --bench fig17_18_accuracy` for the full \
              paper evaluation");
    Ok(())
}
