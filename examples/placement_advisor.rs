//! Placement advisor — the Smart-Arrays / Parallel-Collections use case
//! from the paper's introduction: a library that owns data placement asks
//! the model, at run time, which thread placement and memory layout to use
//! for a given workload, *without* measuring every candidate.
//!
//!     cargo run --release --example placement_advisor [--workload cg]
//!         [--machine xeon8|xeon18] [--threads N] [--sweeps K]
//!
//! Built on `coordinator::advisor`: profile twice → fit → rank every
//! feasible placement through the **batched + placement-cached** serving
//! path (`PredictionService::serve_perf`) → recommend; then validate the
//! recommendation against brute-force simulation of every candidate, and
//! replay the sweep to show repeated what-if queries served from memory.

use numabw::coordinator::{advisor, profile, FitRequest, PredictionService};
use numabw::prelude::*;
use numabw::report;
use numabw::util::args::Args;
use numabw::workloads::suite;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let machine = MachineTopology::by_name(args.get_or("machine", "xeon8"))
        .expect("machine: xeon8|xeon18");
    let workload = suite::by_name(args.get_or("workload", "cg"))
        .expect("workload name from Table 1");
    let total = args.get_usize("threads", machine.cores_per_socket);
    let sweeps = args.get_usize("sweeps", 3).max(1);
    let svc = PredictionService::auto();

    println!("advising placement for `{}` with {total} threads on {}\n",
             workload.name, machine.name);

    // Profile + fit once (the only measurement cost the library pays).
    let sim = Simulator::new(machine.clone(), SimConfig::default());
    let pair = profile(&sim, &workload);
    let sig = svc
        .fit(&[FitRequest { sym: pair.sym, asym: pair.asym }])?
        .pop()
        .expect("one signature");

    // Rank every feasible placement through the serving layer.  Replaying
    // the sweep models the production pattern (many tenants asking the
    // same what-ifs); every pass after the first is pure cache hits.
    let mut advice =
        advisor::advise(&svc, &machine, &workload, &sig, total)?;
    for _ in 1..sweeps {
        advice = advisor::advise(&svc, &machine, &workload, &sig, total)?;
    }
    let stats = svc.cache_stats();

    println!("model ranking (predicted achieved bandwidth):");
    let rows: Vec<Vec<String>> = advice
        .ranked
        .iter()
        .take(5)
        .map(|s| {
            vec![
                format!("{:?}", s.placement.threads_per_socket),
                report::fmt_bw(s.predicted_bw),
                format!("{:.0}%", 100.0 * s.satisfaction()),
                format!("{:.0}%", 100.0 * s.qpi_headroom),
            ]
        })
        .collect();
    print!("{}", report::table(
        &["threads", "predicted bw", "satisfied", "qpi headroom"], &rows));
    println!("\n{} sweeps × {} placements served; cache: {} hits / {} \
              misses", sweeps, advice.ranked.len(), stats.hits(),
             stats.misses());

    // Validate: brute-force simulate every candidate (what the library
    // could never afford in production).
    let mut best_measured: (Option<&ThreadPlacement>, f64) = (None, 0.0);
    for s in &advice.ranked {
        let bw = sim.run(&workload, &s.placement).achieved_bw;
        if bw > best_measured.1 {
            best_measured = (Some(&s.placement), bw);
        }
    }
    let recommended = advice.best();
    let rec_measured =
        sim.run(&workload, &recommended.placement).achieved_bw;
    println!("\nrecommended: {:?} -> measured {}",
             recommended.placement.threads_per_socket,
             report::fmt_bw(rec_measured));
    let (best_p, best_bw) = best_measured;
    println!("true best:   {:?} -> measured {}",
             best_p.expect("non-empty ranking").threads_per_socket,
             report::fmt_bw(best_bw));
    let gap = 100.0 * (1.0 - rec_measured / best_bw);
    println!("regret: {gap:.1}% of the best achievable bandwidth \
              (profiling cost: 2 runs instead of {})",
             advice.ranked.len());
    Ok(())
}
