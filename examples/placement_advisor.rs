//! Placement advisor — the Smart-Arrays / Parallel-Collections use case
//! from the paper's introduction: a library that owns data placement asks
//! the model, at run time, which thread placement and memory layout to use
//! for a given workload, *without* measuring every candidate.
//!
//!     cargo run --release --example placement_advisor [--workload cg]
//!         [--machine xeon8|xeon18] [--threads N]
//!
//! Flow: profile twice → fit → predict achieved bandwidth for every
//! feasible thread split under contention (max-min pipeline) → recommend;
//! then validate the recommendation against brute-force simulation of
//! every candidate.

use numabw::coordinator::{profile, FitRequest, PerfQuery,
                          PredictionService};
use numabw::prelude::*;
use numabw::report;
use numabw::util::args::Args;
use numabw::workloads::suite;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let machine = MachineTopology::by_name(args.get_or("machine", "xeon8"))
        .expect("machine: xeon8|xeon18");
    let workload = suite::by_name(args.get_or("workload", "cg"))
        .expect("workload name from Table 1");
    let total = args.get_usize("threads", machine.cores_per_socket);
    let svc = PredictionService::auto();

    println!("advising placement for `{}` with {total} threads on {}\n",
             workload.name, machine.name);

    // Profile + fit once (the only measurement cost the library pays).
    let sim = Simulator::new(machine.clone(), SimConfig::default());
    let pair = profile(&sim, &workload);
    let sig = &svc.fit(&[FitRequest { sym: pair.sym, asym: pair.asym }])?[0];

    // Score every feasible split through the contention pipeline.  The
    // per-thread demand is latency-adjusted per placement: the signature's
    // own traffic matrix says how remote each socket's accesses will be,
    // and dependent-load workloads slow down accordingly (the same issue-
    // rate model the simulator uses).
    let caps: [f64; 8] = machine.capacities().try_into().unwrap();
    let peak = workload.bw_per_thread.min(machine.core_peak_bw);
    let splits = ThreadPlacement::all_splits(&machine, total);
    let queries: Vec<PerfQuery> = splits
        .iter()
        .map(|p| {
            let m = sig.combined.apply(&p.threads_per_socket);
            // Thread-weighted average latency under this placement.
            let n = p.total().max(1) as f64;
            let mut lat = 0.0;
            for (src, &cnt) in p.threads_per_socket.iter().enumerate() {
                for (dst, w) in m[src].iter().enumerate() {
                    lat += cnt as f64 / n * w * machine.latency_ns(src, dst);
                }
            }
            let scale = (1.0 - workload.latency_sensitivity)
                + workload.latency_sensitivity * machine.local_latency_ns
                    / lat.max(machine.local_latency_ns);
            let per_thread = peak * scale;
            PerfQuery {
                sig: sig.combined,
                threads: [p.threads_per_socket[0], p.threads_per_socket[1]],
                demand_pt: [per_thread * workload.read_fraction,
                            per_thread * (1.0 - workload.read_fraction)],
                caps,
            }
        })
        .collect();
    let predictions = svc.predict_performance(&queries)?;

    let mut scored: Vec<(usize, f64)> = predictions
        .iter()
        .enumerate()
        .map(|(i, alloc)| (i, alloc.iter().sum::<f64>()))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    println!("model ranking (predicted achieved bandwidth):");
    let rows: Vec<Vec<String>> = scored
        .iter()
        .take(5)
        .map(|&(i, bw)| {
            vec![format!("{:?}", splits[i].threads_per_socket),
                 report::fmt_bw(bw)]
        })
        .collect();
    print!("{}", report::table(&["threads", "predicted bw"], &rows));

    // Validate: brute-force simulate every candidate (what the library
    // could never afford in production).
    let mut best_measured = (0usize, 0.0f64);
    for (i, p) in splits.iter().enumerate() {
        let bw = sim.run(&workload, p).achieved_bw;
        if bw > best_measured.1 {
            best_measured = (i, bw);
        }
    }
    let recommended = scored[0].0;
    let rec_measured = sim.run(&workload, &splits[recommended]).achieved_bw;
    println!("\nrecommended: {:?} -> measured {}",
             splits[recommended].threads_per_socket,
             report::fmt_bw(rec_measured));
    println!("true best:   {:?} -> measured {}",
             splits[best_measured.0].threads_per_socket,
             report::fmt_bw(best_measured.1));
    let gap = 100.0 * (1.0 - rec_measured / best_measured.1);
    println!("regret: {gap:.1}% of the best achievable bandwidth \
              (profiling cost: 2 runs instead of {})", splits.len());
    Ok(())
}
